"""Backend-differential harness: the pallas kernel backend vs jnp + oracle.

The tentpole contract of the kernel-backend layer: for every TPC-H query,
``Session(kernel_backend="pallas")`` (Pallas kernels, interpret mode
off-TPU) must produce exactly the rows of the jnp backend (the sort-based
code, which doubles as the kernel oracle) and of the pure-numpy TPC-H
oracle — and ``executor_stats()['kernel_dispatch']`` must show the hot
spots actually ran on the kernels (probe/agg/compact/partition).

Layering mirrors the distributed-oracle suite:

* unmarked tests — fast smoke slice + dispatch/backend plumbing, tier-1;
* ``@pytest.mark.kernel_backend`` — the full 22-query × W∈{1,2} sweep and
  a randomized-config property pass, deselected from the default run
  (pyproject ``addopts``) and executed as its own CI job with
  ``REPRO_KERNEL_BACKEND=pallas``. ``KERNEL_BACKEND_SF`` shrinks it.
"""

from __future__ import annotations

import functools
import os

import pytest

from repro.core import Session
from repro.core import plan as P
from repro.kernels import ops as kernel_ops
from repro.tpch import dbgen, oracle, queries

from _hypothesis_compat import bools, sampled, seeded_given
from tpch_util import assert_results_match

SF = float(os.environ.get("KERNEL_BACKEND_SF", "0.002"))

# dispatch kinds specific queries must exercise under the pallas backend
# (W=2 adds 'partition' whenever the planner places a Repartition).
# "probe|fused" = the probe may run standalone or inside the fused
# per-morsel pipeline kernel, depending on whether it fused into the scan.
EXPECTED_KINDS = {
    1: {"agg"},                          # group-by aggregation
    3: {"probe|fused", "build", "agg"},  # unique-key joins + group-by
    14: {"probe|fused", "build"},        # lineitem x part join
    15: {"compact"},                     # scalar subquery -> compacted scalar
}


def _dispatched(kd: dict, kind: str) -> bool:
    """True when any of the '|'-separated alternative kinds ran."""
    return any(kd.get(k, 0) > 0 for k in kind.split("|"))


@functools.lru_cache(maxsize=2)
def dataset(sf: float):
    """(raw numpy tables, catalog) for one scale factor, cached."""
    return dbgen.generate(sf=sf), dbgen.load_catalog(sf=sf)


def run_backend(catalog, qnum: int, num_workers: int, backend: str,
                batch_rows: int = 8192, streaming: bool = True):
    """Execute ``qnum`` under ``backend``; returns (result, stats)."""
    plan = queries.build_query(qnum, catalog, num_workers=num_workers)
    session = Session(catalog, num_workers=num_workers,
                      kernel_backend=backend, batch_rows=batch_rows,
                      streaming=streaming)
    res = session.execute(plan)
    return res, session.executor_stats()


# ---------------------------------------------------------------------------
# tier-1: dispatch plumbing
# ---------------------------------------------------------------------------

def test_backend_selection_api():
    """use_backend/use_pallas scope the thread; bad names are rejected."""
    assert kernel_ops.current_backend() in kernel_ops.BACKENDS
    with kernel_ops.use_pallas():
        assert kernel_ops.current_backend() == "pallas"
        with kernel_ops.use_backend("jnp"):
            assert kernel_ops.current_backend() == "jnp"
        assert kernel_ops.current_backend() == "pallas"
    with pytest.raises(ValueError):
        with kernel_ops.use_backend("cuda"):
            pass
    with pytest.raises(ValueError):
        kernel_ops.set_default_backend("velox")


def test_session_threads_backend_into_stats():
    """Session(kernel_backend=...) reaches the driver and executor stats."""
    _, catalog = dataset(SF)
    for backend in kernel_ops.BACKENDS:
        _, stats = run_backend(catalog, 6, 1, backend)
        assert stats["kernel_backend"] == backend
    # jnp sessions never count pallas dispatches
    _, stats = run_backend(catalog, 1, 1, "jnp")
    assert stats["kernel_dispatch"] == {}


def test_smoke_slice_matches_oracle_and_jnp():
    """Q1/Q3/Q14 × W∈{1,2}: pallas rows == jnp rows == oracle rows, and
    the expected kernel kinds dispatched (plus 'partition' at W=2)."""
    data, catalog = dataset(SF)
    for qnum in (1, 3, 14):
        ref = oracle.ORACLES[qnum](data)
        for w in (1, 2):
            res_j, _ = run_backend(catalog, qnum, w, "jnp")
            res_p, stats = run_backend(catalog, qnum, w, "pallas")
            assert_results_match(res_p, ref, qnum)
            assert_results_match(res_p, res_j, qnum)
            kd = stats["kernel_dispatch"]
            for kind in EXPECTED_KINDS[qnum]:
                assert _dispatched(kd, kind), (qnum, w, kind, kd)
            if w == 2 and qnum in (1, 3):
                # Q1/Q3 shuffle on group keys at W=2 (Q14's global agg
                # broadcasts instead, which has no metadata histogram)
                assert kd.get("partition", 0) > 0, (qnum, kd)


def test_fused_morsel_dispatch_smoke():
    """The streaming scan's filter->project->probe chain collapses into
    the fused per-morsel kernel under pallas: Q3 and Q6 must report
    'fused' dispatches (and Q3's unique-key joins must not fall back),
    with rows still matching the oracle."""
    data, catalog = dataset(SF)
    for qnum in (3, 6):
        res, stats = run_backend(catalog, qnum, 1, "pallas")
        assert_results_match(res, oracle.ORACLES[qnum](data), qnum)
        kd = stats["kernel_dispatch"]
        assert kd.get("fused", 0) > 0, (qnum, kd)
    assert kd.get("fallback_probe", 0) == 0, kd   # Q3: all joins on-kernel


def test_fused_pipeline_never_dispatches_under_jnp():
    """The morsel-pipeline collapse is pallas-only: a jnp session must
    show no 'fused' (or any other) dispatches."""
    _, catalog = dataset(SF)
    _, stats = run_backend(catalog, 3, 1, "jnp")
    assert stats["kernel_dispatch"] == {}


def test_compact_dispatches_on_scalar_subquery():
    """Q15's scalar-subquery broadcast stream-compacts under the kernel
    backend (block_prefix_sum addresses)."""
    data, catalog = dataset(SF)
    res, stats = run_backend(catalog, 15, 1, "pallas")
    assert_results_match(res, oracle.ORACLES[15](data), 15)
    assert stats["kernel_dispatch"].get("compact", 0) > 0


def test_probe_key_equal_to_empty_sentinel_never_matches():
    """A probe key of -1 (the table's empty sentinel) reads empty slots as
    hits inside the kernel; the operator must mask it to no-match so both
    backends agree (regression: fabricated joins / wrong semi/anti)."""
    import numpy as np

    from repro.core import dtypes as dt
    from repro.core import operators as ops_mod
    from repro.core.table import DeviceTable

    build = DeviceTable.from_numpy(
        {"k": np.asarray([5, 7], np.int32),
         "pay": np.asarray([50, 70], np.int32)},
        {"k": dt.INT32, "pay": dt.INT32})
    probe = DeviceTable.from_numpy(
        {"k": np.asarray([-1, 5, 99], np.int32)},
        {"k": dt.INT32})
    for join_type in ("inner", "left_semi", "left_anti"):
        results = {}
        for backend in kernel_ops.BACKENDS:
            with kernel_ops.use_backend(backend):
                join = ops_mod.HashJoin(
                    ["k"], ["k"], () if "semi" in join_type
                    or "anti" in join_type else ["pay"],
                    join_type=join_type)
                join.open()
                join.add_build(build)
                join.seal_build()
                if backend == "pallas":
                    assert join._hash_state is not None, "fell back"
                (out,) = join.add_input(probe)
                results[backend] = sorted(
                    np.asarray(out.columns["k"])[
                        np.asarray(out.validity)].tolist())
        assert results["pallas"] == results["jnp"], (join_type, results)


def test_sentinel_probe_key_expansion_join():
    """PR-5 sentinel regression ported to the expansion probe: a probe key
    of -1 must never match even though the kernel reads empty slots as
    hits, for every join type, with duplicate build keys exercising
    ``hash_probe_multi``."""
    import numpy as np

    from repro.core import dtypes as dt
    from repro.core import operators as ops_mod
    from repro.core.table import DeviceTable

    build = DeviceTable.from_numpy(
        {"k": np.asarray([5, 5, 7], np.int32),
         "pay": np.asarray([50, 51, 70], np.int32)},
        {"k": dt.INT32, "pay": dt.INT32})
    probe = DeviceTable.from_numpy(
        {"k": np.asarray([-1, 5, 99], np.int32)}, {"k": dt.INT32})
    for join_type in ("inner", "left_outer", "left_semi", "left_anti"):
        payload = () if join_type in ("left_semi", "left_anti") else ["pay"]
        results = {}
        for backend in kernel_ops.BACKENDS:
            with kernel_ops.use_backend(backend):
                join = ops_mod.HashJoin(["k"], ["k"], payload,
                                        join_type=join_type, max_matches=4)
                join.open()
                join.add_build(build)
                join.seal_build()
                if backend == "pallas":
                    assert join._hash_state is not None, "fell back"
                    assert join._multi == (join_type in ("inner",
                                                         "left_outer"))
                (out,) = join.add_input(probe)
                valid = np.asarray(out.validity)
                results[backend] = sorted(
                    np.asarray(out.columns["k"])[valid].tolist())
        assert results["pallas"] == results["jnp"], (join_type, results)
        assert -1 not in results["pallas"] or join_type in (
            "left_anti", "left_outer"), (join_type, results)


def test_sentinel_and_out_of_window_composite_packed_join():
    """PR-5 sentinel regression ported to the packed-composite path: probe
    tuples outside the pack windows (including -1 components) map to the
    empty sentinel and must never match; in-window-but-absent tuples must
    miss; present tuples must hit — identically on both backends."""
    import numpy as np

    from repro.core import dtypes as dt
    from repro.core import operators as ops_mod
    from repro.core.table import DeviceTable

    build = DeviceTable.from_numpy(
        {"a": np.asarray([5, 7], np.int32),
         "b": np.asarray([1, 2], np.int32),
         "pay": np.asarray([50, 70], np.int32)},
        {"a": dt.INT32, "b": dt.INT32, "pay": dt.INT32})
    probe = DeviceTable.from_numpy(
        {"a": np.asarray([-1, 5, 5, 7, 99], np.int32),
         "b": np.asarray([1, 1, 2, 2, 1], np.int32)},
        {"a": dt.INT32, "b": dt.INT32})
    for join_type in ("inner", "left_semi", "left_anti"):
        payload = () if join_type in ("left_semi", "left_anti") else ["pay"]
        results = {}
        for backend in kernel_ops.BACKENDS:
            with kernel_ops.use_backend(backend):
                join = ops_mod.HashJoin(["a", "b"], ["a", "b"], payload,
                                        join_type=join_type, max_matches=1)
                join.open()
                join.add_build(build)
                join.seal_build()
                if backend == "pallas":
                    assert join._hash_state is not None, "no pack derived"
                    assert join._pack is not None
                (out,) = join.add_input(probe)
                valid = np.asarray(out.validity)
                results[backend] = sorted(zip(
                    np.asarray(out.columns["a"])[valid].tolist(),
                    np.asarray(out.columns["b"])[valid].tolist()))
        assert results["pallas"] == results["jnp"], (join_type, results)
    # the inner case (last iteration order-independent check): only the
    # tuples actually present on the build side match
    inner = ops_mod.HashJoin(["a", "b"], ["a", "b"], ["pay"],
                             join_type="inner", max_matches=1)
    with kernel_ops.use_pallas():
        inner.open()
        inner.add_build(build)
        inner.seal_build()
        (out,) = inner.add_input(probe)
        valid = np.asarray(out.validity)
        got = sorted(zip(np.asarray(out.columns["a"])[valid].tolist(),
                         np.asarray(out.columns["b"])[valid].tolist()))
    assert got == [(5, 1), (7, 2)], got


def test_jnp_backend_never_counts_fallbacks():
    """S2 regression: capacity-blocked aggregations and non-kernel joins
    under a *jnp* session must not inflate fallback counters — nothing
    "fell back" when no kernel was requested."""
    import jax.numpy as jnp
    import numpy as np

    from repro.core import relational as rel

    used: set = set()
    with kernel_ops.use_backend("jnp"), kernel_ops.record_kernels(used):
        n = 8
        vals = jnp.asarray(np.arange(n), jnp.float32)
        gids = jnp.zeros((n,), jnp.int32)
        order = jnp.arange(n, dtype=jnp.int32)
        valid = jnp.ones((n,), bool)
        # a group capacity past PALLAS_AGG_GROUP_LIMIT would mark
        # fallback_agg under pallas; under jnp it must mark nothing
        rel.segment_agg(vals, gids, order, valid,
                        rel.PALLAS_AGG_GROUP_LIMIT + 1, "sum")
    assert used == set(), used
    # executor-level: a full jnp query session reports no dispatches at all
    _, catalog = dataset(SF)
    _, stats = run_backend(catalog, 3, 1, "jnp")
    assert not any(k.startswith("fallback") for k in stats["kernel_dispatch"])


def test_agg_group_limit_boundary():
    """S3 off-by-one: the dispatch bound is *inclusive* — exactly
    ``1 << 16`` groups still dispatches the pallas agg kernel, one more
    takes the jnp fallback (and marks it). All three accumulators share
    the bound; the int path must not inherit the old 2^24 count limit."""
    import jax.numpy as jnp

    from repro.core import relational as rel

    assert rel.PALLAS_AGG_GROUP_LIMIT == 1 << 16
    n = 4
    vals = jnp.ones((n,), jnp.float32)
    ivals = jnp.ones((n,), jnp.int32)
    gids = jnp.zeros((n,), jnp.int32)
    order = jnp.arange(n, dtype=jnp.int32)
    valid = jnp.ones((n,), bool)
    with kernel_ops.use_pallas():
        for kind, v in (("sum", vals), ("sum", ivals), ("count", ivals),
                        ("min", ivals), ("max", vals)):
            used: set = set()
            with kernel_ops.record_kernels(used):
                rel.segment_agg(v, gids, order, valid,
                                rel.PALLAS_AGG_GROUP_LIMIT, kind)
            assert "agg" in used and "fallback_agg" not in used, (kind, used)
            used = set()
            with kernel_ops.record_kernels(used):
                rel.segment_agg(v, gids, order, valid,
                                rel.PALLAS_AGG_GROUP_LIMIT + 1, kind)
            assert "fallback_agg" in used and "agg" not in used, (kind, used)


def test_integer_sums_stay_exact_past_float32_range():
    """Integer segmented sums must bypass the float32 kernel accumulator:
    2^24 + 1 + 1 is not representable in float32 (regression: silent
    precision loss on int measures)."""
    import jax.numpy as jnp
    import numpy as np

    from repro.core import relational as rel

    vals = jnp.asarray([1 << 24, 1, 1], jnp.int32)
    gids = jnp.asarray([0, 0, 0], jnp.int32)
    order = jnp.arange(3, dtype=jnp.int32)
    valid = jnp.ones((3,), bool)
    for backend in kernel_ops.BACKENDS:
        with kernel_ops.use_backend(backend):
            out = rel.segment_agg(vals, gids, order, valid, 4, "sum")
        assert int(np.asarray(out)[0]) == (1 << 24) + 2, backend


def test_dispatch_counts_are_per_specialization():
    """A jit specialization that falls back to the jnp path (group
    capacity past the kernel limit — integer and min/max measures now
    dispatch kernels, so capacity is the remaining fallback axis) must
    not replay the kernel counts recorded by an in-capacity
    specialization of the same table_op (regression: over-counting)."""
    import numpy as np

    from repro.core import relational as rel

    counts: dict = {}
    with kernel_ops.use_pallas(), kernel_ops.collect_dispatches(counts):
        # direct segment_agg calls mark only at trace time; go through a
        # table_op to exercise the replay machinery
        from repro.core import dtypes as dt
        from repro.core.operators import _aggregate
        from repro.core.table import DeviceTable

        def agg_with(max_groups):
            t = DeviceTable.from_numpy(
                {"g": np.asarray([0, 1, 0], np.int32),
                 "v": np.asarray([1.0, 2.0, 3.0], np.float32)},
                {"g": dt.INT32, "v": dt.FLOAT32})
            return _aggregate(t, ("g",), (("s", "sum", "v"),), max_groups)

        agg_with(4)
        after_small = counts.get("agg", 0)
        assert after_small > 0
        agg_with(rel.PALLAS_AGG_GROUP_LIMIT + 1)
        assert counts.get("agg", 0) == after_small, counts
        assert counts.get("fallback_agg", 0) > 0, counts


def test_scheduler_run_honors_use_pallas_scope():
    """`with use_pallas(): session.run(q)` must execute (and key its
    caches) under pallas, like the batch path (regression: the scheduled
    path ignored the thread-scoped switch)."""
    _, catalog = dataset(SF)
    session = Session(catalog, num_workers=1)
    plan = queries.build_query(1, catalog)
    with kernel_ops.use_pallas():
        h = session.submit(plan)
        h.result()
    assert h.kernel_backend == "pallas"
    assert h.executor_stats["kernel_backend"] == "pallas"
    assert h.executor_stats["kernel_dispatch"].get("agg", 0) > 0
    session.reset_scheduler()


def test_scheduler_caches_key_on_backend():
    """Flipping session.kernel_backend must miss both caches: a result
    computed by one backend is never served to the other."""
    _, catalog = dataset(SF)
    session = Session(catalog, num_workers=1, kernel_backend="jnp")
    plan = queries.build_query(6, catalog)
    a = session.run(plan)
    session.kernel_backend = "pallas"
    b = session.run(plan)
    stats = session.scheduler().stats()
    assert stats["result_cache_hits"] == 0
    assert stats["result_cache_misses"] == 2
    assert_results_match(a, b, 6)
    # same backend again: now it hits
    session.run(plan)
    assert session.scheduler().stats()["result_cache_hits"] == 1
    session.reset_scheduler()


# ---------------------------------------------------------------------------
# full sweep (own CI job; deselected from tier-1 via pyproject addopts)
# ---------------------------------------------------------------------------

@pytest.mark.kernel_backend
@pytest.mark.parametrize("qnum", sorted(queries.QUERIES))
def test_full_query_sweep_backend_differential(qnum):
    """All 22 queries × W∈{1,2}: pallas == jnp == oracle, with nonzero
    dispatch counts wherever the query shape exercises a kernel."""
    data, catalog = dataset(SF)
    ref = oracle.ORACLES[qnum](data)
    for w in (1, 2):
        res_j, _ = run_backend(catalog, qnum, w, "jnp")
        assert_results_match(res_j, ref, qnum)
        res_p, stats = run_backend(catalog, qnum, w, "pallas")
        assert_results_match(res_p, ref, qnum)
        assert_results_match(res_p, res_j, qnum)
        assert stats["kernel_backend"] == "pallas"
        kd = stats["kernel_dispatch"]
        for kind in EXPECTED_KINDS.get(qnum, ()):
            assert _dispatched(kd, kind), (qnum, w, kind, kd)
        if w == 2 and _has_repartition(qnum, catalog):
            # a planned hash exchange sizes its receive buffers with the
            # radix_histogram kernel (the metadata phase)
            assert kd.get("partition", 0) > 0, (qnum, w, kd)


@pytest.mark.kernel_backend
def test_cold_fallback_coverage():
    """Fallback-gap contract: with expansion probes, composite-key packing
    and the integer/min-max accumulators in place, at least 8 of the 22
    TPC-H queries must report zero probe+agg fallback dispatches on a cold
    (first-run, streaming) pallas session at W=1."""
    _, catalog = dataset(SF)
    clean = []
    for qnum in sorted(queries.QUERIES):
        _, stats = run_backend(catalog, qnum, 1, "pallas")
        kd = stats["kernel_dispatch"]
        if kd.get("fallback_probe", 0) == 0 and kd.get("fallback_agg", 0) == 0:
            clean.append(qnum)
    assert len(clean) >= 8, (len(clean), clean)


def _has_repartition(qnum: int, catalog) -> bool:
    plan = queries.build_query(qnum, catalog, num_workers=2)
    stack = [plan]
    while stack:
        node = stack.pop()
        if isinstance(node, (P.Repartition, P.Exchange)):
            return True
        stack.extend(node.children())
    return False


@pytest.mark.kernel_backend
@seeded_given(max_examples=8, _seed=20260731,
              qnum=sampled(*sorted(queries.QUERIES)), w=sampled(1, 2),
              batch_rows=sampled(2048, 8192), streaming=bools())
def test_property_random_morsel_settings_pallas(qnum, w, batch_rows,
                                                streaming):
    """Randomized batch/streaming settings: the pallas backend must match
    the oracle regardless of how the scan pipeline slices batches."""
    data, catalog = dataset(SF)
    res, stats = run_backend(catalog, qnum, w, "pallas",
                             batch_rows=batch_rows, streaming=streaming)
    assert_results_match(res, oracle.ORACLES[qnum](data), qnum)
    assert stats["kernel_backend"] == "pallas"
