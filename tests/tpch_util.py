"""Shared helpers for TPC-H engine-vs-oracle comparison."""

from __future__ import annotations

import numpy as np


def canon(result: dict, columns) -> list:
    """Canonical multiset of rows over ``columns`` (order-insensitive,
    float-rounded so float32 engine results compare to float64 oracle)."""
    n = len(next(iter(result.values())))
    rows = []
    for i in range(n):
        row = []
        for c in columns:
            v = result[c][i] if hasattr(result[c], "__getitem__") else result[c]
            v = np.asarray(v)
            if v.ndim >= 1 and v.dtype == np.uint8:     # bytes column
                row.append(v.tobytes())
            elif v.dtype.kind == "f":
                x = float(v)
                row.append(round(x / max(abs(x), 1.0), 4))  # relative rounding
            elif v.dtype.kind == "S" or isinstance(result[c][i], bytes):
                row.append(bytes(result[c][i]))
            else:
                row.append(int(v))
        rows.append(tuple(row))
    return sorted(rows)


def assert_results_match(engine: dict, oracle: dict, qnum: int,
                         float_cols_rtol: float = 2e-3):
    common = [c for c in oracle.keys() if c in engine]
    assert common, f"q{qnum}: no common columns {list(engine)} vs {list(oracle)}"
    n_e = len(next(iter(engine.values())))
    n_o = len(next(iter(oracle.values())))
    assert n_e == n_o, f"q{qnum}: row count {n_e} != oracle {n_o}"
    # order-insensitive structural match on non-float columns, then
    # float columns compared after canonical sort
    int_cols = [c for c in common if np.asarray(oracle[c]).dtype.kind in "iub"
                or isinstance(oracle[c][0] if n_o else b"", bytes)]
    flt_cols = [c for c in common if c not in int_cols]
    key_cols = int_cols if int_cols else common

    def sort_rows(res):
        arrays = []
        for c in key_cols + flt_cols:
            a = res[c]
            if isinstance(a, np.ndarray) and a.ndim > 1 and a.dtype == np.uint8:
                a = np.array([row.tobytes() for row in a])
            elif n_o and isinstance(a[0], bytes):
                a = np.asarray(a)
            else:
                a = np.asarray(a, dtype=np.float64)
                a = np.round(a, 2)
            arrays.append(a)
        order = np.lexsort(tuple(reversed(arrays)))
        return order

    eo, oo = sort_rows(engine), sort_rows(oracle)
    for c in int_cols:
        ea, oa = engine[c], oracle[c]
        if isinstance(ea, np.ndarray) and ea.ndim > 1 and ea.dtype == np.uint8:
            ea = np.array([r.tobytes() for r in ea])
        if isinstance(oa, np.ndarray) and oa.ndim > 1 and oa.dtype == np.uint8:
            # reference side may be another engine result (differential
            # engine-vs-engine checks): same bytes-row canonicalization
            oa = np.array([r.tobytes() for r in oa])
        if n_o and isinstance(oa[0], bytes):
            oa = np.asarray(oa)
            ea = np.asarray(ea)
        np.testing.assert_array_equal(np.asarray(ea)[eo], np.asarray(oa)[oo],
                                      err_msg=f"q{qnum} column {c}")
    for c in flt_cols:
        ea = np.asarray(engine[c], dtype=np.float64)[eo]
        oa = np.asarray(oracle[c], dtype=np.float64)[oo]
        np.testing.assert_allclose(ea, oa, rtol=float_cols_rtol, atol=1e-2,
                                   err_msg=f"q{qnum} column {c}")
