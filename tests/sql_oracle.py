"""Cross-engine differential oracle: our SQL frontend vs in-process DuckDB.

The hand-written numpy oracle (``repro.tpch.oracle``) only covers the 22
TPC-H shapes; this harness makes *any* SQL text a correctness check by the
transpile-and-checksum pattern:

1. ``export_catalog`` materializes the registered catalog into an
   in-process DuckDB connection, decoding the engine's storage encodings
   (dict32 codes -> strings, fixed-width bytes -> trimmed varchar,
   date32 day counts -> DATE);
2. the *same SQL text* runs on both engines;
3. ``diff_results`` compares row counts, then per-column MD5 checksums of
   the canonically sorted, stringified values (exact for int/string/date
   columns; float columns compare by ``allclose`` under an rtol matching
   the float32-vs-float64 precision gap).

``fuzz_queries`` is the seeded generator: random filter/join/aggregate
queries over the TPC-H schema, constrained to the engine's supported
surface (PK-covering equi-joins, int/dict group keys) so every generated
query must agree with DuckDB -- a disagreement is an engine bug, never a
"the fuzzer asked for too much" artifact.

DuckDB is an *optional* dependency (the ``[sql]`` pyproject extra); import
this module's ``require_duckdb`` in tests to skip loudly when absent.
"""

from __future__ import annotations

import datetime
import hashlib
import random
from typing import Dict, Iterable, List, Optional

import numpy as np

try:
    import duckdb
    HAVE_DUCKDB = True
    _DUCKDB_ERR = None
except ImportError as _e:          # pragma: no cover - exercised in CI matrix
    duckdb = None
    HAVE_DUCKDB = False
    _DUCKDB_ERR = _e


def require_duckdb():
    """Skip the calling test loudly when duckdb is not installed."""
    if not HAVE_DUCKDB:
        import pytest
        pytest.skip("duckdb is not installed -- install the [sql] extra "
                    f"(pip install 'presto-gpu-repro[sql]'): {_DUCKDB_ERR}")


# ---------------------------------------------------------------------------
# catalog export
# ---------------------------------------------------------------------------

_EPOCH = datetime.date(1970, 1, 1)


def _decode_column(arr: np.ndarray, dt) -> list:
    """Storage-encoded numpy column -> python values DuckDB understands."""
    if dt.name == "dict32":
        d = dt.dictionary
        return [d[int(c)] for c in arr]
    if dt.name == "bytes":
        return [bytes(row).decode("ascii", "replace").rstrip("\x00 ")
                for row in arr]
    if dt.name == "date32":
        return [_EPOCH + datetime.timedelta(days=int(v)) for v in arr]
    if dt.name == "bool":
        return [bool(v) for v in arr]
    if dt.name in ("float32", "float64"):
        return [float(v) for v in arr]
    return [int(v) for v in arr]


_DUCK_TYPES = {
    "int32": "INTEGER", "int64": "BIGINT", "float32": "DOUBLE",
    "float64": "DOUBLE", "bool": "BOOLEAN", "date32": "DATE",
    "dict32": "VARCHAR", "bytes": "VARCHAR",
}


def _host_columns(source) -> Dict[str, np.ndarray]:
    """Full host-side data of a TableSource (InMemoryTable fast path;
    generic sources re-read through their morsel stream)."""
    if hasattr(source, "data"):
        return source.data
    cols: Dict[str, List[np.ndarray]] = {c: [] for c in source.schema}
    for m in source._host_morsels(1, None, 65536):
        for c in source.schema:
            col, valid = m.columns[c][0], m.validity[0]
            cols[c].append(np.asarray(col)[np.asarray(valid)])
    return {c: np.concatenate(v) for c, v in cols.items()}


def export_catalog(con, catalog, tables: Optional[Iterable[str]] = None):
    """Create + populate one DuckDB table per catalog table."""
    for name in sorted(tables if tables is not None else catalog.tables()):
        src = catalog.get(name)
        schema = src.schema
        decl = ", ".join(f'"{c}" {_DUCK_TYPES[t.name]}'
                         for c, t in schema.items())
        con.execute(f'DROP TABLE IF EXISTS "{name}"')
        con.execute(f'CREATE TABLE "{name}" ({decl})')
        data = _host_columns(src)
        decoded = [_decode_column(np.asarray(data[c]), schema[c])
                   for c in schema]
        if decoded and decoded[0]:
            ph = ", ".join("?" for _ in schema)
            con.executemany(f'INSERT INTO "{name}" VALUES ({ph})',
                            list(zip(*decoded)))


def connect_with_catalog(catalog):
    """In-memory DuckDB connection pre-loaded with the catalog."""
    con = duckdb.connect(":memory:")
    export_catalog(con, catalog)
    return con


# ---------------------------------------------------------------------------
# result normalization + diff
# ---------------------------------------------------------------------------

def run_duckdb(con, sql: str) -> Dict[str, list]:
    """Run ``sql`` on DuckDB; returns {column: list-of-python-values}."""
    cur = con.execute(sql)
    names = [d[0] for d in cur.description]
    rows = cur.fetchall()
    return {n: [r[i] for r in rows] for i, n in enumerate(names)}


def _norm_engine(result: Dict[str, np.ndarray], schema) -> Dict[str, list]:
    """Engine result -> comparable python values, decoding through the
    builder's output ``schema`` (dict32 codes, bytes rows, date32 days)."""
    out = {}
    for name, arr in result.items():
        dt = schema.get(name)
        a = np.asarray(arr)
        if dt is not None and dt.name in ("dict32", "bytes", "date32", "bool"):
            out[name] = _decode_column(a, dt)
        elif a.ndim > 1 and a.dtype == np.uint8:    # bytes w/o schema hint
            out[name] = [bytes(r).decode("ascii", "replace").rstrip("\x00 ")
                         for r in a]
        elif a.dtype.kind == "f":
            out[name] = [float(v) for v in a]
        elif a.dtype.kind == "b":
            out[name] = [bool(v) for v in a]
        else:
            out[name] = [int(v) for v in a]
    return out


def _norm_duck(result: Dict[str, list]) -> Dict[str, list]:
    """DuckDB result -> the same comparable python values."""
    out = {}
    for name, vals in result.items():
        norm = []
        for v in vals:
            if isinstance(v, datetime.datetime):
                v = v.date()
            if isinstance(v, datetime.date):
                norm.append(v)
            elif isinstance(v, bool):
                norm.append(v)
            elif isinstance(v, int):
                norm.append(int(v))
            elif isinstance(v, float):
                norm.append(float(v))
            elif isinstance(v, str):
                norm.append(v.rstrip())
            elif v is None:
                norm.append(None)
            else:                                   # Decimal etc.
                norm.append(float(v))
        out[name] = norm
    return out


def _cell_str(v) -> str:
    if isinstance(v, bool):
        return "1" if v else "0"
    if isinstance(v, datetime.date):
        return v.isoformat()
    if isinstance(v, str):
        return v.rstrip()
    if v is None:
        return "<null>"
    return str(int(v))


def _sort_order(cols: Dict[str, list], names: List[str]) -> List[int]:
    """Canonical row order: lexicographic over stringified exact cells,
    with floats relative-rounded so both engines sort identically."""
    def key(i):
        row = []
        for n in names:
            v = cols[n][i]
            if isinstance(v, float) and not isinstance(v, bool):
                row.append(("f", round(v / max(abs(v), 1.0), 4)))
            else:
                row.append(("s", _cell_str(v)))
        return row
    n_rows = len(cols[names[0]]) if names else 0
    return sorted(range(n_rows), key=key)


def column_checksum(values: Iterable[str]) -> str:
    """MD5 over newline-joined canonical cell strings."""
    h = hashlib.md5()
    for v in values:
        h.update(v.encode("utf-8", "replace"))
        h.update(b"\n")
    return h.hexdigest()


class SqlMismatch(AssertionError):
    """The two engines disagreed on the same SQL text."""


def diff_results(engine: Dict[str, np.ndarray], duck: Dict[str, list],
                 schema, sql: str = "", rtol: float = 2e-3,
                 atol: float = 1e-2) -> Dict[str, str]:
    """Compare an engine result against a DuckDB result for the same SQL.

    Raises ``SqlMismatch`` on row-count or checksum/allclose divergence;
    returns the per-column checksums on success (for artifact logging).
    """
    e = _norm_engine(engine, schema)
    d = _norm_duck(duck)
    missing = sorted(set(e) ^ set(d))
    if missing:
        raise SqlMismatch(
            f"column sets differ (engine {sorted(e)} vs duckdb {sorted(d)}; "
            f"odd ones out {missing})\nsql: {sql}")
    names = list(e)
    n_e = len(e[names[0]]) if names else 0
    n_d = len(d[names[0]]) if names else 0
    if n_e != n_d:
        raise SqlMismatch(
            f"row counts differ: engine {n_e} vs duckdb {n_d}\nsql: {sql}")

    float_cols = [n for n in names
                  if any(isinstance(v, float) and not isinstance(v, bool)
                         for v in e[n] + d[n])]
    eo, do = _sort_order(e, names), _sort_order(d, names)
    checksums = {}
    for n in names:
        ev = [e[n][i] for i in eo]
        dv = [d[n][i] for i in do]
        if n in float_cols:
            ea = np.array([np.nan if v is None else float(v) for v in ev])
            da = np.array([np.nan if v is None else float(v) for v in dv])
            if not np.allclose(ea, da, rtol=rtol, atol=atol, equal_nan=True):
                bad = int(np.argmax(~np.isclose(ea, da, rtol=rtol, atol=atol,
                                                equal_nan=True)))
                raise SqlMismatch(
                    f"float column '{n}' diverges at sorted row {bad}: "
                    f"engine {ea[bad]!r} vs duckdb {da[bad]!r}\nsql: {sql}")
            checksums[n] = f"allclose:{len(ea)}"
        else:
            ce = column_checksum(_cell_str(v) for v in ev)
            cd = column_checksum(_cell_str(v) for v in dv)
            if ce != cd:
                diff_at = next((i for i in range(len(ev))
                                if _cell_str(ev[i]) != _cell_str(dv[i])), -1)
                raise SqlMismatch(
                    f"column '{n}' checksum mismatch ({ce} vs {cd}); first "
                    f"divergent sorted row {diff_at}: "
                    f"engine {ev[diff_at]!r} vs duckdb {dv[diff_at]!r}"
                    f"\nsql: {sql}")
            checksums[n] = ce
    return checksums


def check_sql(session, con, sql: str, rtol: float = 2e-3) -> Dict[str, str]:
    """Run ``sql`` on both engines and diff; returns per-column checksums."""
    qb = session.sql(sql)
    engine = qb.collect()
    duck = run_duckdb(con, sql)
    return diff_results(engine, duck, qb.schema, sql=sql, rtol=rtol)


# ---------------------------------------------------------------------------
# seeded SQL fuzzer over the TPC-H schema
# ---------------------------------------------------------------------------

# (probe, build, probe_key, build_key): every join builds on the build
# table's primary key, so the lowering's unique-coverage requirement holds
# by construction and the engine's static match capacities are exact
_JOINS = [
    ("lineitem", "orders", "l_orderkey", "o_orderkey"),
    ("lineitem", "part", "l_partkey", "p_partkey"),
    ("lineitem", "supplier", "l_suppkey", "s_suppkey"),
    ("orders", "customer", "o_custkey", "c_custkey"),
    ("partsupp", "part", "ps_partkey", "p_partkey"),
    ("partsupp", "supplier", "ps_suppkey", "s_suppkey"),
    ("customer", "nation", "c_nationkey", "n_nationkey"),
    ("supplier", "nation", "s_nationkey", "n_nationkey"),
]

# per-table columns by role: int keys we may group/select, float measures,
# date columns, dict32 columns (grouped or compared by equality)
_TABLES = {
    "lineitem": dict(pk=None, ints=["l_orderkey", "l_linenumber",
                                    "l_partkey", "l_suppkey"],
                     floats=["l_quantity", "l_extendedprice", "l_discount",
                             "l_tax"],
                     dates=["l_shipdate", "l_commitdate", "l_receiptdate"],
                     dicts=["l_returnflag", "l_linestatus", "l_shipmode"]),
    "orders": dict(pk="o_orderkey", ints=["o_orderkey", "o_custkey",
                                          "o_shippriority"],
                   floats=["o_totalprice"], dates=["o_orderdate"],
                   dicts=["o_orderpriority", "o_orderstatus"]),
    "customer": dict(pk="c_custkey", ints=["c_custkey", "c_nationkey"],
                     floats=["c_acctbal"], dates=[], dicts=["c_mktsegment"]),
    "part": dict(pk="p_partkey", ints=["p_partkey", "p_size"],
                 floats=["p_retailprice"], dates=[],
                 dicts=["p_brand", "p_container", "p_mfgr"]),
    "supplier": dict(pk="s_suppkey", ints=["s_suppkey", "s_nationkey"],
                     floats=["s_acctbal"], dates=[], dicts=[]),
    "partsupp": dict(pk=None, ints=["ps_partkey", "ps_suppkey",
                                    "ps_availqty"],
                     floats=["ps_supplycost"], dates=[], dicts=[]),
    "nation": dict(pk="n_nationkey", ints=["n_nationkey", "n_regionkey"],
                   floats=[], dates=[], dicts=["n_name"]),
}

_AGGS = ["count", "sum", "avg", "min", "max"]


def _sample_literal(rng: random.Random, catalog, table: str, column: str):
    """A literal drawn from the live column data (filters stay selective
    but never vacuous)."""
    src = catalog.get(table)
    dt = src.schema[column]
    data = _host_columns(src)[column]
    v = data[rng.randrange(len(data))]
    if dt.name == "dict32":
        return "'" + dt.dictionary[int(v)] + "'"
    if dt.name == "date32":
        return "DATE '" + (_EPOCH + datetime.timedelta(days=int(v))).isoformat() + "'"
    if dt.name in ("float32", "float64"):
        # full repr of the float32 value: both engines parse it to exactly
        # the stored value, so comparisons agree at the boundary row
        return repr(float(v))
    return str(int(v))


def _filter(rng: random.Random, catalog, table: str, cols) -> str:
    kind = rng.choice(["int", "float", "date", "dict"])
    pool = {"int": cols["ints"], "float": cols["floats"],
            "date": cols["dates"], "dict": cols["dicts"]}[kind]
    if not pool:
        pool, kind = cols["ints"], "int"
    c = rng.choice(pool)
    lit = _sample_literal(rng, catalog, table, c)
    if kind == "dict":
        return f"{c} {rng.choice(['=', '<>'])} {lit}"
    op = rng.choice(["<", "<=", ">", ">=", "="])
    return f"{c} {op} {lit}"


def _agg_items(rng: random.Random, cols) -> List[str]:
    items = ["count(*) AS cnt"]
    for i in range(rng.randint(1, 3)):
        kind = rng.choice(_AGGS)
        if kind == "count":
            continue
        pool = cols["floats"] or cols["ints"]
        c = rng.choice(pool)
        if kind in ("sum", "avg") and c not in cols["floats"]:
            kind = rng.choice(["min", "max"])
        items.append(f"{kind}({c}) AS agg{i}")
    return items


def fuzz_queries(seed: int, n: int, catalog) -> List[str]:
    """``n`` deterministic random SQL texts over the TPC-H schema, all
    inside the engine's supported surface (so any cross-engine diff is a
    real bug)."""
    rng = random.Random(seed)
    out = []
    while len(out) < n:
        shape = rng.choice(["scan_agg", "group", "join_agg", "join_group",
                            "scan_rows"])
        if shape in ("scan_agg", "group", "scan_rows"):
            t = rng.choice(sorted(_TABLES))
            cols = _TABLES[t]
            where = " AND ".join(_filter(rng, catalog, t, cols)
                                 for _ in range(rng.randint(1, 2)))
            if shape == "scan_agg":
                out.append(f"SELECT {', '.join(_agg_items(rng, cols))} "
                           f"FROM {t} WHERE {where}")
            elif shape == "group":
                keys = rng.sample(cols["ints"] + cols["dicts"],
                                  rng.randint(1, 2))
                sel = ", ".join(keys + _agg_items(rng, cols))
                out.append(f"SELECT {sel} FROM {t} WHERE {where} "
                           f"GROUP BY {', '.join(keys)} "
                           f"ORDER BY {', '.join(keys)}")
            else:
                if cols["pk"] is None:
                    continue
                extra = [c for c in cols["ints"] + cols["floats"]
                         if c != cols["pk"]]
                sel = ", ".join([cols["pk"]] + rng.sample(
                    extra, min(len(extra), rng.randint(1, 2))))
                out.append(f"SELECT {sel} FROM {t} WHERE {where} "
                           f"ORDER BY {cols['pk']}")
        else:
            probe, build, pk_col, bk_col = rng.choice(_JOINS)
            pc, bc = _TABLES[probe], _TABLES[build]
            where = [_filter(rng, catalog, probe, pc)]
            if rng.random() < 0.7:
                where.append(_filter(rng, catalog, build, bc))
            cond = " AND ".join([f"{pk_col} = {bk_col}"] + where)
            if shape == "join_agg":
                out.append(f"SELECT {', '.join(_agg_items(rng, pc))} "
                           f"FROM {probe}, {build} WHERE {cond}")
            else:
                pool = bc["dicts"] + bc["ints"]
                keys = rng.sample(pool, min(len(pool), rng.randint(1, 2)))
                sel = ", ".join(keys + _agg_items(rng, pc))
                out.append(f"SELECT {sel} FROM {probe}, {build} "
                           f"WHERE {cond} GROUP BY {', '.join(keys)} "
                           f"ORDER BY {', '.join(keys)}")
    return out


def fuzz_small_queries(seed: int, n: int, catalog) -> List[str]:
    """``n`` deterministic *small-query* SQL texts: the serving-side
    point-lookup / low-cardinality-group-by corpus the inter-query
    batching scheduler exists for (``tests/test_batching.py``).

    Every text is a single-table scan -> filter -> project/aggregate with
    no ORDER BY — the plan family ``core.batch.extract_shape`` accepts —
    and within each template only the comparison literals vary, so texts
    from the same template are mutually compatible for stacked launches.
    Texts that still fall outside the batchable surface (e.g. a date
    filter the optimizer rewrites) simply run solo: the differential
    contract is identical either way, a DuckDB diff is an engine bug."""
    rng = random.Random(seed)
    pk_tables = [t for t in sorted(_TABLES) if _TABLES[t]["pk"]]
    dict_tables = [t for t in sorted(_TABLES) if _TABLES[t]["dicts"]]
    out: List[str] = []
    while len(out) < n:
        mode = len(out) % 3
        if mode == 0:            # point lookup on a primary key
            t = rng.choice(pk_tables)
            cols = _TABLES[t]
            pk = cols["pk"]
            extra = [c for c in cols["ints"] + cols["floats"] if c != pk]
            sel = ", ".join([pk] + rng.sample(extra, min(2, len(extra))))
            out.append(f"SELECT {sel} FROM {t} WHERE {pk} = "
                       f"{_sample_literal(rng, catalog, t, pk)}")
        elif mode == 1:          # filtered global aggregate
            t = rng.choice(sorted(_TABLES))
            cols = _TABLES[t]
            out.append(f"SELECT {', '.join(_agg_items(rng, cols))} "
                       f"FROM {t} WHERE {_filter(rng, catalog, t, cols)}")
        else:                    # low-cardinality group-by (dict32 key)
            t = rng.choice(dict_tables)
            cols = _TABLES[t]
            key = rng.choice(cols["dicts"])
            sel = ", ".join([key] + _agg_items(rng, cols))
            out.append(f"SELECT {sel} FROM {t} "
                       f"WHERE {_filter(rng, catalog, t, cols)} "
                       f"GROUP BY {key}")
    return out
