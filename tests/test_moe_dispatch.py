"""MoE dispatch paths: GSPMD bucket layout vs explicit shard_map dispatch
(§Perf hillclimb 3) must agree numerically; capacity drops must be benign."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import moe, moe_a2a
from repro.models.moe import init_moe, moe_ffn


@pytest.fixture()
def setup():
    cfg = get_config("dbrx_132b", smoke=True)
    params = init_moe(jax.random.key(0), cfg)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(0, 1, (2, 32, cfg.d_model)), jnp.bfloat16)
    return cfg, params, x


def test_gspmd_and_explicit_agree_without_mesh(setup):
    cfg, params, x = setup
    y_g, aux_g = moe_ffn(params, x, cfg)
    y_e, aux_e = moe_a2a.moe_ffn_a2a(params, x, cfg)   # falls back local
    np.testing.assert_allclose(np.asarray(y_e, np.float32),
                               np.asarray(y_g, np.float32),
                               rtol=2e-2, atol=2e-2)
    np.testing.assert_allclose(float(aux_e), float(aux_g), rtol=1e-3)


def test_explicit_path_under_real_mesh(setup):
    """shard_map path on a 1x1 mesh (degenerate but exercises psum/axis
    machinery; multi-device covered by the dry-run lowering)."""
    from jax.sharding import Mesh
    from repro.models.sharding import Axes, use_axes

    cfg, params, x = setup
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    axes = Axes(dp=("data",), tp="model", dp_size=1, tp_size=1)
    y_g, _ = moe_ffn(params, x, cfg)
    with mesh, use_axes(axes, mesh):
        y_e, _ = moe_a2a.moe_ffn_a2a(params, x, cfg)
    np.testing.assert_allclose(np.asarray(y_e, np.float32),
                               np.asarray(y_g, np.float32),
                               rtol=2e-2, atol=2e-2)


def test_moe_dispatch_flag_switches(setup):
    cfg, params, x = setup
    old = moe.MOE_DISPATCH
    try:
        moe.MOE_DISPATCH = "a2a"
        y1, _ = moe_ffn(params, x, cfg)
    finally:
        moe.MOE_DISPATCH = old
    y0, _ = moe_ffn(params, x, cfg)
    np.testing.assert_allclose(np.asarray(y1, np.float32),
                               np.asarray(y0, np.float32),
                               rtol=2e-2, atol=2e-2)


def test_shared_experts_path():
    cfg = get_config("deepseek_moe_16b", smoke=True)
    params = init_moe(jax.random.key(1), cfg)
    x = jnp.asarray(np.random.default_rng(1).normal(0, 1, (1, 16, cfg.d_model)),
                    jnp.bfloat16)
    y_g, _ = moe_ffn(params, x, cfg)
    y_e, _ = moe_a2a.moe_ffn_a2a(params, x, cfg)
    np.testing.assert_allclose(np.asarray(y_e, np.float32),
                               np.asarray(y_g, np.float32),
                               rtol=2e-2, atol=2e-2)
