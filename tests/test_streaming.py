"""Streaming executor tests: morsel prefetcher, TableSource.stream(),
per-morsel fused pipelines, zone-map skipping end-to-end, executor stats."""

import numpy as np
import pytest

from repro.core import Session, dtypes as dt
from repro.core.expr import col, lit
from repro.core.operators import FilterProject, HashAggregation, Pipeline
from repro.core.streaming import (HostMorsel, MorselPrefetcher, ScanStats,
                                  morsel_to_device)
from repro.storage import (ColumnChunkTable, PagedTableSource, write_paged_table,
                           write_table)
from repro.tpch import dbgen, queries


def _data(n=1000):
    rng = np.random.default_rng(7)
    return {
        "k": np.arange(n, dtype=np.int32),
        "v": rng.random(n).astype(np.float32),
        "s": dt.encode_bytes([f"row{i}" for i in range(n)], 8),
    }


SCHEMA = {"k": dt.INT32, "v": dt.FLOAT32, "s": dt.bytes_(8)}


def _collect(batches):
    """Valid rows of a stream of batches, per column (to_numpy masks)."""
    out = {}
    for b in batches:
        for c, a in b.to_numpy().items():
            out.setdefault(c, []).append(a)
    return {c: np.concatenate(v) for c, v in out.items()}


def _assert_same(got, want):
    assert set(got) == set(want)
    for c in want:
        np.testing.assert_array_equal(got[c], want[c])


# -- stream() == scan() across every backend --------------------------------

def _make_sources(tmp_path, n=1000, chunks=4):
    from repro.core.session import InMemoryTable
    data = _data(n)
    write_table(str(tmp_path), "t", data, SCHEMA, chunks=chunks)
    write_paged_table(str(tmp_path), "t", data, SCHEMA, row_groups=chunks)
    return data, [
        InMemoryTable("t", data, SCHEMA),
        ColumnChunkTable(str(tmp_path), "t"),
        PagedTableSource(str(tmp_path), "t"),
    ]


@pytest.mark.parametrize("workers", [1, 3])
def test_stream_matches_scan_all_backends(tmp_path, workers):
    _, sources = _make_sources(tmp_path)
    for src in sources:
        scanned = _collect(src.scan(workers, None, 256))
        stats = ScanStats()
        streamed = _collect(src.stream(workers, None, 256, stats=stats))
        _assert_same(streamed, scanned)
        assert stats.morsels > 0
        assert stats.bytes_transferred > 0
        assert stats.read_seconds > 0


def test_paged_source_roundtrip_matches_inmemory(tmp_path):
    data, (mem, cc, paged) = _make_sources(tmp_path)
    want = _collect(mem.scan(2, None, 512))
    _assert_same(_collect(cc.scan(2, None, 512)), want)
    _assert_same(_collect(paged.scan(2, None, 512)), want)
    for c in data:
        np.testing.assert_array_equal(np.sort(want[c], axis=0),
                                      np.sort(data[c], axis=0))


# -- zone-map skipping: identical results with skipping on/off --------------

@pytest.mark.parametrize("backend", ["colchunk", "paged"])
def test_skipping_on_off_identical(tmp_path, backend):
    data = _data(4000)
    pred = (col("k") >= lit(500)) & (col("k") < lit(900))
    if backend == "colchunk":
        write_table(str(tmp_path), "t", data, SCHEMA, chunks=8)
        on = ColumnChunkTable(str(tmp_path), "t", skip_with_stats=True)
        off = ColumnChunkTable(str(tmp_path), "t", skip_with_stats=False)
    else:
        write_paged_table(str(tmp_path), "t", data, SCHEMA, row_groups=8)
        on = PagedTableSource(str(tmp_path), "t", skip_with_stats=True)
        off = PagedTableSource(str(tmp_path), "t", skip_with_stats=False)

    def run(src):
        fp = FilterProject(pred)
        got = []
        for m in src.stream(1, None, 1 << 20, filter_expr=pred):
            got.extend(fp.add_input(m))
        return _collect(got)

    r_on, r_off = run(on), run(off)
    _assert_same(r_on, r_off)
    np.testing.assert_array_equal(np.sort(r_on["k"]), np.arange(500, 900))
    assert on.chunks_skipped > 0          # pruned without being read
    assert off.chunks_skipped == 0


def test_all_chunks_skipped_yields_empty_morsel(tmp_path):
    data = _data(1000)
    write_table(str(tmp_path), "t", data, SCHEMA, chunks=4)
    src = ColumnChunkTable(str(tmp_path), "t")
    pred = col("k") > lit(10_000_000)
    batches = list(src.stream(2, None, 1 << 20, filter_expr=pred))
    assert len(batches) == 1              # shape-preserving empty morsel
    assert int(batches[0].num_valid()) == 0
    assert src.chunks_skipped == 4


# -- prefetcher behavior -----------------------------------------------------

def _host_gen(n_morsels, fail_at=None):
    for i in range(n_morsels):
        if fail_at is not None and i == fail_at:
            raise RuntimeError("storage exploded")
        yield HostMorsel({"k": np.full((1, 8), i, dtype=np.int32)},
                         np.ones((1, 8), dtype=bool), {"k": dt.INT32})


def test_prefetcher_preserves_order_and_counts():
    stats = ScanStats()
    got = [int(np.asarray(t.columns["k"])[0, 0])
           for t in MorselPrefetcher(_host_gen(7), depth=2, stats=stats)]
    assert got == list(range(7))
    assert stats.morsels == 7
    assert stats.bytes_transferred > 0


def test_prefetcher_early_abandon_stops_producer():
    pf = MorselPrefetcher(_host_gen(100), depth=2)
    it = iter(pf)
    next(it), next(it)
    it.close()                            # downstream Limit abandons the scan
    pf._thread.join(timeout=5)
    assert not pf._thread.is_alive()


def test_prefetcher_propagates_reader_errors():
    pf = MorselPrefetcher(_host_gen(5, fail_at=2), depth=2)
    with pytest.raises(RuntimeError, match="storage exploded"):
        list(pf)


def test_morsel_to_device_roundtrip():
    m = HostMorsel({"k": np.arange(6, dtype=np.int32).reshape(1, 6)},
                   np.ones((1, 6), dtype=bool), {"k": dt.INT32})
    t = morsel_to_device(m)
    np.testing.assert_array_equal(np.asarray(t.columns["k"]),
                                  m.columns["k"])


# -- Pipeline operator -------------------------------------------------------

def test_pipeline_composes_like_sequential():
    from repro.core.session import InMemoryTable
    data = _data(2000)
    src = InMemoryTable("t", data, SCHEMA)
    pred = col("k") < lit(1200)
    pipe = Pipeline([
        FilterProject(pred, [("v2", col("v") * lit(2.0))]),
        HashAggregation([], [("s", "sum", "v2"), ("n", "count", None)],
                        "single", 1),
    ])
    pipe.open()
    outs = []
    for b in src.scan(1, None, 300):
        outs.extend(pipe.add_input(b))
    outs.extend(pipe.finish())
    got = _collect(outs)
    want = data["v"][data["k"] < 1200] * 2.0
    assert int(got["n"][0]) == 1200
    np.testing.assert_allclose(got["s"][0], want.sum(), rtol=1e-5)


# -- driver + session integration -------------------------------------------

@pytest.fixture(scope="module")
def storage_setup(tmp_path_factory):
    root = str(tmp_path_factory.mktemp("tpch_stream"))
    data = dbgen.write_dataset(root, sf=0.002, chunks=8)
    return root, data


def test_streaming_session_equals_sync(storage_setup):
    root, _ = storage_setup
    for qnum in (1, 6):
        cat_a = dbgen.storage_catalog(root)
        cat_b = dbgen.storage_catalog(root)
        res_s = Session(cat_a, num_workers=2, streaming=True).execute(
            queries.build_query(qnum, cat_a))
        res_m = Session(cat_b, num_workers=2, streaming=False).execute(
            queries.build_query(qnum, cat_b))
        for c in res_s:
            np.testing.assert_allclose(res_s[c], res_m[c], rtol=1e-5)


def test_explain_analyze_reports_skipping(storage_setup):
    root, _ = storage_setup
    cat = dbgen.storage_catalog(root)
    session = Session(cat, num_workers=2)
    text = session.explain(queries.build_query(6, cat), analyze=True)
    assert "== executor stats ==" in text
    line = next(l for l in text.splitlines()
                if l.startswith("scan lineitem"))
    skipped = int(line.split("chunks_skipped=")[1].split()[0])
    assert skipped > 0                    # Q6's date range prunes chunks
    stats = session.executor_stats()
    li = stats["tables"]["lineitem"]
    assert li["bytes_read"] > 0
    assert li["bytes_transferred"] > 0
    assert 0.0 <= li["prefetch_overlap"] <= 1.0


def test_sync_mode_populates_scan_stats(storage_setup):
    root, _ = storage_setup
    cat = dbgen.storage_catalog(root)
    session = Session(cat, num_workers=2, streaming=False)
    session.execute(queries.build_query(6, cat))
    li = session.executor_stats()["tables"]["lineitem"]
    assert li["morsels"] > 0
    assert li["bytes_read"] > 0
    assert li["bytes_transferred"] > 0
    assert li["chunks_skipped"] > 0


def test_legacy_scan_only_source_still_streams():
    """A TableSource written against the pre-morsel contract (overrides
    scan() only) must keep working through stream() and the driver."""
    from repro.core import Catalog, TableSource, plan as P
    from repro.core.table import DeviceTable

    data = _data(500)

    class Legacy(TableSource):
        name = "legacy"
        schema = SCHEMA

        def num_rows(self):
            return 500

        def scan(self, num_workers, columns, batch_rows, filter_expr=None):
            cols = list(columns) if columns else list(data)
            for lo in range(0, 500, 200):
                hi = min(lo + 200, 500)
                yield DeviceTable.from_numpy(
                    {c: data[c][lo:hi] for c in cols},
                    {c: SCHEMA[c] for c in cols})

    class LegacyStacked(Legacy):
        # DeviceTable.from_numpy yields unstacked [cap] batches; wrap to
        # the worker-stacked layout the driver expects
        def scan(self, num_workers, columns, batch_rows, filter_expr=None):
            for b in super().scan(1, columns, batch_rows, filter_expr):
                yield DeviceTable(
                    {c: a[None] for c, a in b.columns.items()},
                    b.validity[None], b.schema)

    src = LegacyStacked()
    stats = ScanStats()
    streamed = _collect(src.stream(1, None, 200, stats=stats))
    _assert_same(streamed, _collect(src.scan(1, None, 200)))
    assert stats.morsels == 3
    assert stats.bytes_transferred > 0

    cat = Catalog()
    cat.register(src)
    session = Session(cat, num_workers=1)
    res = session.execute(P.TableScan("legacy", columns=["k"],
                                      filter=col("k") < lit(100)))
    np.testing.assert_array_equal(np.sort(res["k"]), np.arange(100))


def test_limit_over_storage_stream_terminates(storage_setup):
    root, _ = storage_setup
    from repro.core import plan as P
    cat = dbgen.storage_catalog(root)
    session = Session(cat, num_workers=2)
    res = session.execute(P.Limit(P.TableScan("lineitem",
                                              columns=["l_orderkey"]), 5))
    assert len(res["l_orderkey"]) == 5
