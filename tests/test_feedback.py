"""Adaptive-execution feedback loop: store properties + warm-replan oracle.

Two layers lock down ``core.feedback`` (ROADMAP "Adaptive execution"):

* unmarked tests — tier-1: q-error algebra (property-tested via the
  ``_hypothesis_compat`` shim), capacity-normalized ``plan.feedback_key``
  stability, store bucketing/version invalidation, warm-bound soundness
  and tightness on a TPC-H slice, the scheduler's plan-cache q-error
  eviction + convergence, and the empty ``executor_stats`` shape
  regression (direct path and scheduler path must agree before any query
  runs);
* ``@pytest.mark.adaptive`` — the full 22-query cold-vs-warm sweep across
  the streaming, distributed (W=2), and pallas backend modes, plus the
  fallback-reduction contract: on warm runs the re-derived capacities must
  keep strictly more work on the pallas kernels for every query whose
  static bounds forced jnp fallbacks cold. Deselected from the default
  run (pyproject ``addopts``); its own CI job executes it.

Env knobs: ``ADAPTIVE_SF`` (oracle sweep scale, default 0.002) and
``ADAPTIVE_FALLBACK_SF`` (fallback-reduction scale, default 0.02 — large
enough that static aggregation bounds exceed the pallas group-capacity
limit, so cold runs genuinely fall back).
"""

from __future__ import annotations

import functools
import os

import numpy as np
import pytest

from repro.core import Session
from repro.core import plan as P
from repro.core.driver import empty_executor_stats
from repro.core.expr import col
from repro.core.feedback import FeedbackStore, qerror, referenced_sources
from repro.core.scheduler import SchedulerConfig
from repro.tpch import dbgen, oracle, queries

from _hypothesis_compat import ints, seeded_given
from tpch_util import assert_results_match

SF = float(os.environ.get("ADAPTIVE_SF", "0.002"))
FALLBACK_SF = float(os.environ.get("ADAPTIVE_FALLBACK_SF", "0.02"))


@functools.lru_cache(maxsize=2)
def dataset(sf: float):
    """(raw numpy tables, catalog) for one scale factor, cached."""
    return dbgen.generate(sf=sf), dbgen.load_catalog(sf=sf)


def fallback_count(stats) -> int:
    """Total jnp-fallback dispatches a pallas-backend query reported."""
    kd = stats.get("kernel_dispatch") or {}
    return sum(v for k, v in kd.items() if k.startswith("fallback"))


# ---------------------------------------------------------------------------
# tier-1: q-error algebra
# ---------------------------------------------------------------------------

@seeded_given(max_examples=50, est=ints(0, 1 << 20), obs=ints(0, 1 << 20))
def test_qerror_symmetric_and_bounded(est, obs):
    """q-error is multiplicative-symmetric, >= 1, and 1.0 iff exact
    (after the 1-row floor)."""
    q = qerror(est, obs)
    assert q == qerror(obs, est)
    assert q >= 1.0
    if max(est, 1) == max(obs, 1):
        assert q == 1.0
    else:
        assert q > 1.0


@seeded_given(max_examples=50, obs=ints(1, 1 << 16), lo=ints(0, 1 << 10),
              hi=ints(0, 1 << 10))
def test_qerror_monotone_in_overestimate(obs, lo, hi):
    """For a fixed observation, walking the estimate further above it
    never decreases the q-error (and symmetrically below)."""
    a, b = sorted((obs + lo, obs + lo + hi))
    assert qerror(a, obs) <= qerror(b, obs)
    a, b = sorted((max(obs - lo, 1), max(obs - lo - hi, 1)), reverse=True)
    assert qerror(a, obs) <= qerror(b, obs)


def test_qerror_floors_zero_rows():
    """Empty results and zero estimates stay finite (floored at 1 row)."""
    assert qerror(0, 0) == 1.0
    assert qerror(0, 10) == 10.0
    assert qerror(10, 0) == 10.0


# ---------------------------------------------------------------------------
# tier-1: capacity-normalized plan keys
# ---------------------------------------------------------------------------

def _scan():
    return P.TableScan("lineitem", columns=("l_orderkey", "l_quantity"))


def test_feedback_key_ignores_derived_capacities():
    """Plans that differ only in optimizer-derived knobs (capacities,
    agg mode, join distribution) share one feedback key, so a warm
    re-plan reads the observations the differently-sized cold plan
    wrote. Semantic fields still split the key."""
    agg = P.Aggregation(_scan(), ["l_orderkey"], [("n", "count", None)])
    resized = P.Aggregation(_scan(), ["l_orderkey"], [("n", "count", None)],
                            max_groups=1 << 20, mode="partial")
    assert P.feedback_key(agg) == P.feedback_key(resized)
    other_key = P.Aggregation(_scan(), ["l_quantity"],
                              [("n", "count", None)])
    assert P.feedback_key(agg) != P.feedback_key(other_key)

    probe = P.TableScan("lineitem", columns=("l_orderkey",))
    build = P.TableScan("orders", columns=("o_orderkey",))
    join = P.Join(probe, build, ["l_orderkey"], ["o_orderkey"])
    sized = P.Join(probe, build, ["l_orderkey"], ["o_orderkey"],
                   max_matches=7, distribution="partitioned",
                   build_rows=123)
    assert P.feedback_key(join) == P.feedback_key(sized)
    semi = P.Join(probe, build, ["l_orderkey"], ["o_orderkey"],
                  join_type="left_semi")
    assert P.feedback_key(join) != P.feedback_key(semi)


def test_feedback_key_looks_through_exchanges():
    """Repartition/Broadcast/Exchange wrappers are transparent: the
    pre-placement planning node and the exchange-wrapped executed node
    key to the same entry. Nested wrappers collapse too, and children
    inside a kept node are normalized the same way."""
    agg = P.Aggregation(_scan(), ["l_orderkey"], [("n", "count", None)])
    assert P.feedback_key(P.Repartition(agg, ["l_orderkey"])) \
        == P.feedback_key(agg)
    assert P.feedback_key(P.Broadcast(P.Repartition(agg, ["l_orderkey"]),
                                      num_workers=2)) == P.feedback_key(agg)
    probe = P.TableScan("lineitem", columns=("l_orderkey",))
    build = P.TableScan("orders", columns=("o_orderkey",))
    wrapped = P.Join(probe, P.Broadcast(build, num_workers=2),
                     ["l_orderkey"], ["o_orderkey"])
    bare = P.Join(probe, build, ["l_orderkey"], ["o_orderkey"])
    assert P.feedback_key(wrapped) == P.feedback_key(bare)


def test_feedback_key_stable_across_equivalent_plans():
    """Rebuilding the same logical plan object-for-object gives the same
    key string (keys must be value-, not identity-, derived)."""
    def build():
        return P.Aggregation(
            P.Filter(_scan(), col("l_quantity") < 10.0),
            ["l_orderkey"], [("s", "sum", "l_quantity")])
    assert P.feedback_key(build()) == P.feedback_key(build())


# ---------------------------------------------------------------------------
# tier-1: store bucketing + bookkeeping
# ---------------------------------------------------------------------------

def test_store_buckets_workers_and_versions():
    """key_for buckets by worker count and by the versions of every table
    the subtree scans: re-registering a referenced table orphans the old
    observations by construction (nothing to invalidate explicitly)."""
    _, catalog = dataset(SF)
    fb = FeedbackStore()
    agg = P.Aggregation(_scan(), ["l_orderkey"], [("n", "count", None)])
    assert referenced_sources(agg) == ("lineitem",)
    k1 = fb.key_for(agg, catalog, 1)
    assert k1 != fb.key_for(agg, catalog, 2)
    fb.record(k1, rows=42, estimated=100)
    src = catalog.get("lineitem")
    catalog.register(src)               # version bump, same data
    k1b = fb.key_for(agg, catalog, 1)
    assert k1b != k1
    assert fb.rows(k1b) is None         # stale entry no longer matches
    assert fb.rows(k1) == 42


def test_store_record_and_summary():
    fb = FeedbackStore()
    e = fb.record("k", rows=10, estimated=100)
    assert e.qerror == 10.0
    fb.record("k", rows=20, max_matches=3, skip_fraction=0.5)
    entry = fb.get("k")
    assert (entry.rows, entry.max_matches, entry.skip_fraction,
            entry.updates) == (20, 3, 0.5, 2)
    # get() is observation-side; rows() counts a planner hit
    assert fb.get("k").hits == 0
    assert fb.rows("k") == 20
    s = fb.summary()
    assert s["entries"] == 1 and s["updates"] == 2 and s["hits"] == 1
    # qerror reflects the estimate in force when it was recorded (the
    # estimate-less second record leaves it untouched)
    assert s["max_qerror"] == pytest.approx(qerror(100, 10))
    fb.clear()
    assert len(fb) == 0


# ---------------------------------------------------------------------------
# tier-1: executor_stats shape (regression: used to be a bare {})
# ---------------------------------------------------------------------------

def test_executor_stats_shape_before_any_query():
    """Both stats surfaces expose every key before a query runs, with the
    exact shape a Driver reports after one — callers can index
    ``stats['kernel_dispatch']``/``['feedback']`` unconditionally."""
    _, catalog = dataset(SF)
    shape = set(empty_executor_stats())
    session = Session(catalog)
    assert set(session.executor_stats()) == shape
    handle = session.submit(queries.build_query(6, catalog))
    assert set(handle.executor_stats) == shape     # possibly still queued
    handle.result()
    assert set(handle.executor_stats) == shape
    session.execute(session.optimize(queries.build_query(6, catalog)))
    assert set(session.executor_stats()) == shape
    session.reset_scheduler()


def test_executor_stats_feedback_summary():
    """With feedback on, the stats' ``feedback`` entry is the live store
    summary (accumulates across queries); off, it stays empty."""
    _, catalog = dataset(SF)
    session = Session(catalog, feedback=True)
    assert session.executor_stats()["feedback"]["entries"] == 0
    session.execute(session.optimize(queries.build_query(6, catalog)))
    assert session.executor_stats()["feedback"]["entries"] > 0
    plain = Session(catalog)
    plain.execute(plain.optimize(queries.build_query(6, catalog)))
    assert plain.executor_stats()["feedback"] == {}


# ---------------------------------------------------------------------------
# tier-1: warm bounds are sound and tighter, results identical
# ---------------------------------------------------------------------------

def _agg_bounds(plan):
    """[(node, max_groups)] for every Aggregation/Distinct in the tree."""
    out = []

    def visit(node):
        if isinstance(node, (P.Aggregation, P.Distinct)):
            out.append((node, node.max_groups))
        for c in node.children():
            visit(c)

    visit(plan)
    return out


@pytest.mark.parametrize("qnum", [3, 5, 10])
def test_warm_bounds_sound_and_tight(qnum):
    """Second (warm) runs of Q3/Q5/Q10 re-derive every aggregation bound
    from the cold run's observations: each warm ``max_groups`` must cover
    the observed group count (soundness) without exceeding the static
    bound (tightness), and the warm result must match cold and oracle."""
    data, catalog = dataset(SF)
    session = Session(catalog, feedback=True)
    fb = session.feedback_store()
    q = queries.build_query(qnum, catalog)
    cold_plan = session.optimize(q)
    cold = session.execute(cold_plan)
    warm_plan = session.optimize(q)
    warm = session.execute(warm_plan)

    assert_results_match(warm, cold, qnum)
    assert_results_match(warm, oracle.ORACLES[qnum](data), qnum)

    static = dict((P.feedback_key(n), mg) for n, mg in _agg_bounds(cold_plan))
    checked = 0
    for node, warm_mg in _agg_bounds(warm_plan):
        observed = fb.rows(fb.key_for(node, catalog, 1))
        if observed is None:
            continue
        checked += 1
        assert warm_mg >= observed, (qnum, warm_mg, observed)
        assert warm_mg <= static[P.feedback_key(node)], \
            (qnum, warm_mg, static[P.feedback_key(node)])
    assert checked > 0, f"q{qnum}: no aggregation bound was re-derived"


def test_feedback_off_is_inert():
    """A feedback-less session never grows a store and plans statically
    (guards against accidental always-on adaptivity)."""
    _, catalog = dataset(SF)
    session = Session(catalog)
    q = queries.build_query(3, catalog)
    p1 = session.optimize(q)
    session.execute(p1)
    p2 = session.optimize(q)
    assert session.feedback_store() is None
    assert P.fingerprint(p1) == P.fingerprint(p2)


# ---------------------------------------------------------------------------
# tier-1: scheduler plan-cache q-error eviction + convergence
# ---------------------------------------------------------------------------

def test_scheduler_replans_then_converges():
    """Cold plan is cached, found drifted after execution (q-error past
    the limit), and evicted; the warm re-plan's estimates match its own
    observations, so the third submit is a plan-cache hit."""
    _, catalog = dataset(SF)
    session = Session(
        catalog, feedback=True,
        scheduler_config=SchedulerConfig(cache_results=False))
    q = queries.build_query(3, catalog)
    h1 = session.submit(q)
    h1.result()
    h2 = session.submit(q)
    h2.result()
    h3 = session.submit(q)
    h3.result()
    assert not h1.plan_cache_hit
    assert not h2.plan_cache_hit       # cold entry was q-error-evicted
    assert h3.plan_cache_hit           # warm entry converged and stays
    assert h1._est_map and h2._est_map
    assert_results_match(h2.result(), h1.result(), 3)
    session.reset_scheduler()


def test_scheduler_static_plans_stay_cached():
    """Without feedback there is no q-error signal: identical submits hit
    the plan cache exactly as before this subsystem existed."""
    _, catalog = dataset(SF)
    session = Session(
        catalog, scheduler_config=SchedulerConfig(cache_results=False))
    q = queries.build_query(3, catalog)
    h1 = session.submit(q)
    h1.result()
    h2 = session.submit(q)
    h2.result()
    assert not h1.plan_cache_hit
    assert h2.plan_cache_hit
    assert h1._est_map == {} == h2._est_map
    session.reset_scheduler()


# ---------------------------------------------------------------------------
# -m adaptive: full cold-vs-warm TPC-H sweep, three backend modes
# ---------------------------------------------------------------------------

MODES = {
    "streaming": dict(),
    "w2": dict(num_workers=2),
    "pallas": dict(kernel_backend="pallas"),
}


@pytest.mark.adaptive
@pytest.mark.parametrize("mode", sorted(MODES))
@pytest.mark.parametrize("qnum", sorted(queries.QUERIES))
def test_warm_replan_oracle_sweep(qnum, mode):
    """Every TPC-H query, run cold then warm on one shared feedback
    store, must produce oracle-identical results in every backend mode —
    adaptivity may only change capacities/ordering, never answers."""
    data, catalog = dataset(SF)
    session = Session(catalog, feedback=True, **MODES[mode])
    w = session.num_workers
    q = queries.build_query(qnum, catalog, num_workers=w)
    cold = session.execute(session.optimize(q))
    warm = session.execute(session.optimize(q))
    ref = oracle.ORACLES[qnum](data)
    assert_results_match(cold, ref, qnum)
    assert_results_match(warm, ref, qnum)
    assert_results_match(warm, cold, qnum)


def _drop_compiled_state():
    """Release every cached jit executable before a full-suite sweep.

    The parametrized oracle sweep leaves thousands of compiled CPU
    executables alive in one process; starting another 22-query pallas
    sweep on top of that state can segfault XLA's CPU compiler. Each
    sweep below passes standalone — clearing restores those conditions
    (at the cost of recompiling, which the sweeps pay anyway)."""
    import jax

    from repro.core import operators
    operators.clear_compile_caches()
    jax.clear_caches()


@pytest.mark.adaptive
def test_warm_runs_reduce_pallas_fallbacks():
    """At a scale where static bounds overflow the pallas capacities, the
    warm re-plan must strictly reduce the jnp-fallback dispatch count for
    every query that fell back cold — and at least 3 such queries must
    exist, or the scale no longer exercises the contract."""
    _drop_compiled_state()
    _, catalog = dataset(FALLBACK_SF)
    session = Session(catalog, feedback=True, kernel_backend="pallas")
    reduced, regressed = [], []
    for qnum in sorted(queries.QUERIES):
        q = queries.build_query(qnum, catalog)
        session.execute(session.optimize(q))
        cold = fallback_count(session.executor_stats())
        session.execute(session.optimize(q))
        warm = fallback_count(session.executor_stats())
        if warm > cold:
            regressed.append((qnum, cold, warm))
        if cold > 0 and warm < cold:
            reduced.append((qnum, cold, warm))
        if cold > 0 and warm >= cold:
            regressed.append((qnum, cold, warm))
    assert not regressed, f"warm runs did not reduce fallbacks: {regressed}"
    assert len(reduced) >= 3, (
        f"only {len(reduced)} queries showed fallback reduction at "
        f"sf={FALLBACK_SF}: {reduced}")


@pytest.mark.adaptive
def test_warm_replan_scheduler_sweep_w2():
    """The serving path at W=2: every query submitted twice through the
    scheduler (result cache off so warm really re-executes) stays
    oracle-identical, and the feedback store accumulates entries."""
    _drop_compiled_state()
    data, catalog = dataset(SF)
    session = Session(
        catalog, num_workers=2, feedback=True,
        scheduler_config=SchedulerConfig(cache_results=False))
    try:
        for qnum in sorted(queries.QUERIES):
            q = queries.build_query(qnum, catalog, num_workers=2)
            cold = session.submit(q).result()
            warm = session.submit(q).result()
            ref = oracle.ORACLES[qnum](data)
            assert_results_match(cold, ref, qnum)
            assert_results_match(warm, ref, qnum)
        assert session.executor_stats()["feedback"]["entries"] > 0
    finally:
        session.reset_scheduler()
