"""Scheduler edge cases: admission control, backpressure, cache
invalidation, and interleaved-query correctness (the serving layer of the
paper's multi-query coordinator)."""

import threading

import numpy as np
import pytest

from repro.core import (QueryRejected, SchedulerConfig, Session, dtypes as dt,
                        plan as P)
from repro.core.optimizer import estimate_memory
from repro.core.session import InMemoryTable
from repro.tpch import dbgen, oracle, queries
from repro.tpch import schema as S

from tpch_util import assert_results_match

SF = 0.002


@pytest.fixture(scope="module")
def data():
    return dbgen.generate(sf=SF)


@pytest.fixture()
def catalog():
    # function-scoped: tests mutate the catalog (re-registration)
    return dbgen.load_catalog(sf=SF)


class GatedTable(InMemoryTable):
    """InMemoryTable whose scan blocks until ``gate`` is set (lets tests
    hold a query 'running' deterministically)."""

    def __init__(self, name, data, schema, gate):
        super().__init__(name, data, schema)
        self.gate = gate

    def _host_morsels(self, *args, **kwargs):
        assert self.gate.wait(timeout=30.0), "test gate never opened"
        yield from super()._host_morsels(*args, **kwargs)


def _tiny_table(catalog, name, gate=None):
    data = {"k": np.arange(8, dtype=np.int32),
            "v": np.ones(8, dtype=np.float32)}
    schema = {"k": dt.INT32, "v": dt.FLOAT32}
    if gate is None:
        catalog.register(InMemoryTable(name, data, schema))
    else:
        catalog.register(GatedTable(name, data, schema, gate))


def _wait_until_running(session, n: int, timeout: float = 10.0) -> None:
    """Spin until ``n`` queries are actively running (past the queue)."""
    import time
    deadline = time.monotonic() + timeout
    while session.scheduler().stats()["running"] < n:
        assert time.monotonic() < deadline, "query never started running"
        time.sleep(0.005)


# ---------------------------------------------------------------------------
# admission control
# ---------------------------------------------------------------------------

def test_over_disk_ceiling_query_rejected(catalog):
    # past the spill disk ceiling not even the disk tier absorbs the
    # excess: the query is rejected, with an explainable breakdown
    session = Session(catalog, num_workers=1)
    session.scheduler_config = SchedulerConfig(memory_budget=1024,
                                               spill_disk_ceiling=1024)
    with pytest.raises(QueryRejected, match="memory budget") as ei:
        session.submit(queries.build_query(1, catalog))
    # the message alone explains the decision: per-operator footprint
    # breakdown plus the tier-crossing spill-cost estimate
    msg = str(ei.value)
    assert "TableScan(lineitem)" in msg and "spill cost" in msg
    assert session.scheduler().stats()["rejected"] == 1


def test_over_budget_query_admitted_with_spill(catalog, data):
    # over the memory budget but under the disk ceiling: admitted with a
    # priced slowdown and executed out-of-core (nonzero spilled bytes)
    session = Session(catalog, num_workers=1, batch_rows=4096)
    session.scheduler_config = SchedulerConfig(memory_budget=64 * 1024)
    handle = session.submit(queries.build_query(3, catalog))
    assert handle.spill_plan is not None
    assert handle.spill_plan["excess_bytes"] > 0
    assert handle.spill_plan["est_slowdown"] > 1.0
    assert handle.memory_breakdown.total == handle.footprint
    assert handle.estimate == 64 * 1024    # charged the whole budget
    res = handle.result(timeout=300)
    assert_results_match(res, oracle.ORACLES[3](data), 3)
    stats = session.scheduler().stats()
    assert stats["spill_admitted"] == 1 and stats["rejected"] == 0
    spill = handle.executor_stats.get("spill", {})
    assert spill.get("spilled_bytes", 0) > 0


def test_queue_full_backpressure(catalog):
    gate = threading.Event()
    _tiny_table(catalog, "gated", gate=gate)
    session = Session(catalog, num_workers=1)
    session.scheduler_config = SchedulerConfig(
        max_concurrency=1, max_queue=1, cache_results=False)
    try:
        # first query occupies the single worker (blocked on the gate);
        # second fills the one queue slot; third must be rejected
        running = session.submit(P.TableScan("gated"))
        _wait_until_running(session, 1)
        queued = session.submit(P.Limit(P.TableScan("gated"), 1))
        with pytest.raises(QueryRejected, match="queue full"):
            session.submit(P.Limit(P.TableScan("gated"), 2))
    finally:
        gate.set()
    assert len(session.gather(running, queued)) == 2
    assert session.scheduler().stats()["rejected"] == 1


def test_priority_orders_the_wait_queue(catalog):
    gate = threading.Event()
    _tiny_table(catalog, "gated", gate=gate)
    _tiny_table(catalog, "plain")
    session = Session(catalog, num_workers=1)
    session.scheduler_config = SchedulerConfig(
        max_concurrency=1, cache_results=False)
    try:
        blocker = session.submit(P.TableScan("gated"))
        _wait_until_running(session, 1)
        low = session.submit(P.Limit(P.TableScan("plain"), 1), priority=0)
        high = session.submit(P.Limit(P.TableScan("plain"), 2), priority=5)
    finally:
        gate.set()
    session.gather(blocker, low, high)
    assert high.started_at < low.started_at, \
        "higher-priority query should leave the queue first"


def test_memory_estimate_scales_with_plan():
    catalog = dbgen.load_catalog(sf=SF)
    scan = P.TableScan("lineitem")
    joined = P.Join(probe=scan, build=P.TableScan("orders"),
                    probe_keys=["l_orderkey"], build_keys=["o_orderkey"],
                    build_payload=["o_orderdate"])
    e_scan = estimate_memory(scan, catalog)
    e_join = estimate_memory(joined, catalog)
    assert 0 < e_scan < e_join, (e_scan, e_join)


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------

def test_result_cache_serves_repeats(catalog):
    session = Session(catalog, num_workers=1)
    first = session.submit(queries.build_query(6, catalog, optimized=False))
    first.result(timeout=60)
    repeat = session.submit(queries.build_query(6, catalog, optimized=False))
    assert repeat.cache_hit
    np.testing.assert_array_equal(repeat.result()["revenue"],
                                  first.result()["revenue"])
    stats = session.scheduler().stats()
    assert stats["result_cache_hits"] == 1
    # a result-cache hit short-circuits before optimization, so the plan
    # cache is untouched on the repeat
    assert stats["plan_cache_hits"] == 0


def test_plan_cache_skips_reoptimization(catalog):
    session = Session(catalog, num_workers=1)
    session.scheduler_config = SchedulerConfig(cache_results=False)
    for _ in range(2):
        session.submit(queries.build_query(6, catalog,
                                           optimized=False)).result(timeout=60)
    stats = session.scheduler().stats()
    assert stats["plan_cache_hits"] == 1 and stats["result_cache_hits"] == 0


def test_result_cache_invalidated_by_reregistration(catalog, data):
    session = Session(catalog, num_workers=1)
    plan = queries.build_query(6, catalog, optimized=False)
    session.run(plan)
    assert session.submit(plan).cache_hit

    # re-register lineitem with the first 100 rows: new table version, so
    # the cached (full-table) result must NOT be served
    small = {k: v[:100] for k, v in data["lineitem"].items()}
    catalog.register_numpy("lineitem", small, S.SCHEMAS["lineitem"])
    handle = session.submit(plan)
    assert not handle.cache_hit, "stale result served after re-registration"
    handle.result(timeout=60)

    small_oracle = oracle.ORACLES[6]({**data, "lineitem": small})
    np.testing.assert_allclose(
        np.asarray(handle.result()["revenue"], dtype=np.float64).reshape(()),
        np.asarray(small_oracle["revenue"], dtype=np.float64).reshape(()),
        rtol=2e-3, atol=1e-2)


def test_midquery_reregistration_does_not_poison_cache(catalog):
    """A table re-registered while a query over it runs must invalidate
    that query's cached result (admission-time version snapshot)."""
    gate = threading.Event()
    _tiny_table(catalog, "gated", gate=gate)
    session = Session(catalog, num_workers=1)
    running = session.submit(P.TableScan("gated"))
    _wait_until_running(session, 1)
    # new data under the same name, mid-query
    catalog.register_numpy("gated", {"k": np.arange(3, dtype=np.int32),
                                     "v": np.ones(3, dtype=np.float32)},
                           {"k": dt.INT32, "v": dt.FLOAT32})
    # an identical submit now must NOT coalesce onto the v1 execution:
    # its admission-time versions no longer match the live catalog
    dup = session.submit(P.TableScan("gated"))
    assert dup is not running, "coalesced onto a stale in-flight query"
    assert len(dup.result(timeout=30)["k"]) == 3
    gate.set()
    old = running.result(timeout=30)
    assert len(old["k"]) == 8              # ran against the old table
    fresh = session.submit(P.TableScan("gated"))
    assert not fresh.cache_hit, "stale mid-query result served from cache"
    assert len(fresh.result(timeout=30)["k"]) == 3


def test_inflight_duplicates_coalesce(catalog):
    gate = threading.Event()
    _tiny_table(catalog, "gated", gate=gate)
    session = Session(catalog, num_workers=1)
    session.scheduler_config = SchedulerConfig(max_concurrency=1)
    try:
        a = session.submit(P.TableScan("gated"))
        b = session.submit(P.TableScan("gated"))
    finally:
        gate.set()
    assert a is b, "identical in-flight queries should share one handle"
    assert session.scheduler().stats()["coalesced"] == 1
    a.result(timeout=30)


def test_fingerprint_canonicalizes_sequences():
    a = P.TableScan("lineitem", columns=["l_quantity", "l_discount"])
    b = P.TableScan("lineitem", columns=("l_quantity", "l_discount"))
    c = P.TableScan("lineitem", columns=["l_discount", "l_quantity"])
    assert P.fingerprint(a) == P.fingerprint(b)
    assert P.fingerprint(a) != P.fingerprint(c)


# ---------------------------------------------------------------------------
# interleaved execution correctness
# ---------------------------------------------------------------------------

def test_interleaved_q1_q6_oracle_correct(catalog, data):
    """4 concurrent Q1/Q6 queries (caching off: four real executions whose
    morsel pipelines interleave) all produce oracle-correct results."""
    session = Session(catalog, num_workers=1, batch_rows=8192)
    session.scheduler_config = SchedulerConfig(
        max_concurrency=4, cache_results=False)
    plans = [queries.build_query(q, catalog, optimized=False)
             for q in (1, 6, 1, 6)]
    handles = [session.submit(p) for p in plans]
    results = session.gather(*handles)
    for qnum, res in zip((1, 6, 1, 6), results):
        assert_results_match(res, oracle.ORACLES[qnum](data), qnum)
    stats = session.scheduler().stats()
    assert stats["completed"] == 4 and stats["failed"] == 0


class FailingTable(InMemoryTable):
    """Table whose scan raises mid-read (storage failure injection)."""

    def _host_morsels(self, *args, **kwargs):
        raise RuntimeError("disk on fire")
        yield  # pragma: no cover -- makes this a generator


def test_failed_query_raises_through_handle(catalog):
    data = {"k": np.arange(8, dtype=np.int32)}
    catalog.register(FailingTable("flaky", data, {"k": dt.INT32}))
    session = Session(catalog, num_workers=1)
    # a failure inside the worker thread must surface through the handle,
    # not kill the scheduler (the next query still runs)
    bad = session.submit(P.TableScan("flaky"))
    with pytest.raises(RuntimeError, match="disk on fire"):
        bad.result(timeout=60)
    ok = session.submit(P.Limit(P.TableScan("orders"), 1))
    assert len(next(iter(ok.result(timeout=60).values()))) == 1
    stats = session.scheduler().stats()
    assert stats["failed"] == 1 and stats["completed"] == 1
