"""Tiered-memory spill subsystem tests (core.spill + spill-aware operators).

Three layers of coverage:

* ``SpillManager`` unit/property tests — reservation accounting,
  largest-first victim selection, and *bit-exact* tier round-trips through
  host buffers and the paged disk format (extreme int64 values included:
  the disk codec must not rely on the paged format's delta encoding).
* Forced-spill differentials — a device budget far below the working set
  makes every memory-hungry operator (grace join, flushing aggregation)
  take its spill path; results must stay oracle-identical and the spill
  counters must show real tier crossings.
* The full 22-query out-of-core sweep at ~1/4 of the estimated footprint,
  across the streaming/distributed/pallas paths (``out_of_core`` marker:
  slow, runs as its own CI job).
"""

import os

import numpy as np
import pytest

from repro.core import ICIExchange, Session, dtypes as dt
from repro.core.spill import (HostMemoryBudget, SpillCapacityError,
                              SpillManager)
from repro.core.table import DeviceTable
from repro.tpch import dbgen, oracle, queries

from _hypothesis_compat import ints, sampled, seeded_given
from tpch_util import assert_results_match

SF = 0.002


@pytest.fixture(scope="module")
def data():
    return dbgen.generate(sf=SF)


@pytest.fixture(scope="module")
def catalog():
    return dbgen.load_catalog(sf=SF)


# ---------------------------------------------------------------------------
# SpillManager: reservations
# ---------------------------------------------------------------------------

def test_reservation_accounting():
    mgr = SpillManager(device_budget=1000)
    assert mgr.reserve("a", 600) == 600
    assert mgr.reserve("b", 600) == 400          # clipped to what's left
    assert mgr.stats.reserve_denials == 1
    assert mgr.device_reserved() == 1000
    assert mgr.device_available() == 0
    mgr.release("a")
    assert mgr.device_reserved() == 400
    assert mgr.reserved("a") == 0 and mgr.reserved("b") == 400
    mgr.release("b", 100)                        # partial release
    assert mgr.reserved("b") == 300
    assert mgr.stats.reserved_peak == 1000
    mgr.close()


def test_reserve_minimum_oversubscribes_for_progress():
    # a zero-available budget still grants the minimum: operators always
    # make progress, the budget just goes (accounted) negative
    mgr = SpillManager(device_budget=100)
    assert mgr.reserve("big", 100) == 100
    assert mgr.reserve("next", 500, minimum=64) == 64
    assert mgr.device_available() == -64
    assert mgr.stats.reserve_denials == 1
    mgr.close()


def test_should_stage_tracks_available_budget():
    mgr = SpillManager(device_budget=1000)
    assert not mgr.should_stage(800)
    mgr.reserve("op", 600)
    assert mgr.should_stage(800)
    assert not mgr.should_stage(400)
    mgr.close()


def test_host_budget_progress_guarantee():
    budget = HostMemoryBudget(100)
    # an oversize request is admitted when nothing is held
    assert budget.acquire(500)
    assert budget.in_use == 500
    assert not budget.try_acquire(1)             # full now
    budget.release(500)
    assert budget.try_acquire(80) and budget.try_acquire(20)
    assert not budget.try_acquire(1)
    budget.release(100)


# ---------------------------------------------------------------------------
# SpillManager: tiers and victim selection
# ---------------------------------------------------------------------------

def _part(n_rows: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    cols = {"k": rng.integers(-1 << 62, 1 << 62, n_rows, dtype=np.int64),
            "v": rng.standard_normal(n_rows).astype(np.float32)}
    validity = rng.random(n_rows) < 0.9
    schema = {"k": dt.INT64, "v": dt.FLOAT32}
    return cols, validity, schema


def test_largest_first_victim_selection(tmp_path):
    small = _part(10, seed=1)
    large = _part(1000, seed=2)
    mid = _part(100, seed=3)
    mgr = SpillManager(device_budget=0, host_budget=2000,
                       spill_dir=str(tmp_path))
    mgr.put_host("small", *small)
    mgr.put_host("large", *large)                # overflows the host tier
    mgr.put_host("mid", *mid)
    # the largest partition is the disk victim; the small ones stay hot
    assert mgr.tier_of("large") == "disk"
    assert mgr.tier_of("small") == "host"
    assert mgr.stats.disk.spills >= 1
    assert mgr.stats.host.spills == 3            # all passed through host
    # restores drain both tiers and delete the disk file
    for key, (cols, validity, _schema) in [("large", large), ("small", small),
                                           ("mid", mid)]:
        got_cols, got_validity, _ = mgr.restore_host(key)
        np.testing.assert_array_equal(got_validity, validity)
        for c in cols:
            np.testing.assert_array_equal(got_cols[c], cols[c])
    assert mgr.keys() == []
    assert not any(f.endswith(".paged") for f in os.listdir(tmp_path))
    mgr.close()


def test_disk_ceiling_raises(tmp_path):
    mgr = SpillManager(device_budget=0, host_budget=0,
                       spill_dir=str(tmp_path), disk_ceiling=64)
    with pytest.raises(SpillCapacityError, match="disk ceiling"):
        mgr.put_host("p", *_part(1000))
    mgr.close()


def test_close_removes_own_spill_dir():
    mgr = SpillManager(device_budget=0, host_budget=0)   # every put -> disk
    mgr.put_host("p", *_part(100))
    root = mgr._dir()
    assert os.path.isdir(root)
    mgr.close()
    assert not os.path.isdir(root)
    # counters survive close for executor_stats
    assert mgr.stats.disk.spills == 1


# ---------------------------------------------------------------------------
# tier round-trips are bit-exact (property)
# ---------------------------------------------------------------------------

@seeded_given(max_examples=15,
              dtype_name=sampled("int32", "int64", "float32", "float64",
                                 "bool", "bytes"),
              n_rows=ints(1, 300),
              stacked=sampled(False, True),
              force_disk=sampled(False, True),
              seed=ints(0, 1 << 30))
def test_tier_roundtrip_bit_exact(tmp_path, dtype_name, n_rows, stacked,
                                  force_disk, seed):
    rng = np.random.default_rng(seed)
    shape = (2, n_rows) if stacked else (n_rows,)
    if dtype_name == "bytes":
        d = dt.bytes_(7)
        arr = rng.integers(0, 256, shape + (7,), dtype=np.uint8)
    elif dtype_name == "bool":
        d = dt.BOOL
        arr = rng.random(shape) < 0.5
    elif dtype_name.startswith("int"):
        d = {"int32": dt.INT32, "int64": dt.INT64}[dtype_name]
        info = np.iinfo(d.np_dtype())
        # extremes included: the disk codec must not delta-encode
        arr = rng.integers(info.min, info.max, shape, dtype=d.np_dtype())
        arr.flat[0] = info.min
        arr.flat[-1] = info.max
    else:
        d = {"float32": dt.FLOAT32, "float64": dt.FLOAT64}[dtype_name]
        arr = rng.standard_normal(shape).astype(d.np_dtype())
    validity = rng.random(shape[:2] if stacked else shape) < 0.8
    mgr = SpillManager(device_budget=0,
                       host_budget=0 if force_disk else 1 << 30,
                       spill_dir=str(tmp_path))
    mgr.put_host("p", {"c": arr}, validity, {"c": d})
    assert mgr.tier_of("p") == ("disk" if force_disk else "host")
    cols, got_validity, schema = mgr.restore_host("p")
    assert schema["c"].name == d.name
    np.testing.assert_array_equal(got_validity, validity)
    np.testing.assert_array_equal(cols["c"], arr)   # bit-exact
    assert cols["c"].dtype == arr.dtype and cols["c"].shape == arr.shape
    mgr.close()


def test_spill_device_table_roundtrip():
    cols, validity, schema = _part(64, seed=7)
    table = DeviceTable.from_numpy(cols, schema)
    mgr = SpillManager(device_budget=0, host_budget=0)   # straight to disk
    nbytes = mgr.spill_table("t", table)
    assert nbytes == table.nbytes()
    back = mgr.restore("t")
    for c in cols:
        np.testing.assert_array_equal(np.asarray(back.columns[c]),
                                      np.asarray(table.columns[c]))
    np.testing.assert_array_equal(np.asarray(back.validity),
                                  np.asarray(table.validity))
    mgr.close()


# ---------------------------------------------------------------------------
# bytes-aware prefetcher shares the host budget
# ---------------------------------------------------------------------------

def test_prefetcher_is_bytes_aware(catalog):
    from repro.core.streaming import MorselPrefetcher

    src = catalog.get("lineitem")
    budget = HostMemoryBudget(1)     # every morsel oversubscribes alone
    pre = MorselPrefetcher(
        src._host_morsels(1, ["l_orderkey"], 1024, None),
        depth=2, host_budget=budget)
    rows = sum(int(t.num_valid()) for t in pre)
    assert rows == src.num_rows()
    # all acquired bytes were released as the consumer drained
    assert budget.in_use == 0


def test_scan_shares_spill_host_budget(catalog, data):
    # the driver hands the spill manager's host meter to every scan: with
    # a budget this small, each morsel proceeds only via the
    # empty-tier progress guarantee, and the query still completes
    session = Session(catalog, num_workers=1, batch_rows=2048,
                      device_budget=1 << 20, host_budget=1)
    res = session.execute(queries.build_query(6, catalog))
    assert_results_match(res, oracle.ORACLES[6](data), 6)


# ---------------------------------------------------------------------------
# forced-spill differentials (fast tier-1 slice)
# ---------------------------------------------------------------------------

# join-heavy (3, 18), aggregation-heavy (1, 13), scan+filter (6, 14)
_FAST_QUERIES = [1, 3, 6, 13, 14, 18]


@pytest.mark.parametrize("qnum", _FAST_QUERIES)
def test_tiny_budget_oracle_identical(qnum, data, catalog):
    session = Session(catalog, num_workers=1, batch_rows=4096,
                      device_budget=16 * 1024)
    res = session.execute(queries.build_query(qnum, catalog))
    assert_results_match(res, oracle.ORACLES[qnum](data), qnum)
    spill = session.executor_stats()["spill"]
    if qnum in (3, 13, 18):       # joins/high-cardinality aggs must spill
        assert spill["spilled_bytes"] > 0, spill


def test_tiny_budget_disk_tier_exercised(data, catalog):
    # host budget squeezed too: victims cascade to paged disk files
    session = Session(catalog, num_workers=1, batch_rows=4096,
                      device_budget=512, host_budget=4096)
    res = session.execute(queries.build_query(3, catalog))
    assert_results_match(res, oracle.ORACLES[3](data), 3)
    spill = session.executor_stats()["spill"]
    assert spill["disk"]["spills"] > 0 and spill["disk"]["restores"] > 0
    # partitions proven unmatchable are dropped, not restored
    assert spill["disk"]["restored_bytes"] <= spill["disk"]["spilled_bytes"]


def test_tiny_budget_distributed(data, catalog):
    session = Session(catalog, num_workers=4, exchange=ICIExchange(),
                      batch_rows=2048, device_budget=16 * 1024)
    res = session.execute(queries.build_query(3, catalog))
    assert_results_match(res, oracle.ORACLES[3](data), 3)
    assert session.executor_stats()["spill"]["spilled_bytes"] > 0


# ---------------------------------------------------------------------------
# full out-of-core sweep (own CI job)
# ---------------------------------------------------------------------------

def _quarter_budget(session, plan) -> int:
    from repro.core.optimizer import estimate_memory
    est = estimate_memory(session.optimize(plan), session.catalog,
                          num_workers=session.num_workers,
                          batch_rows=session.batch_rows,
                          prefetch_depth=session.prefetch_depth)
    return max(est // 4, 1024)


@pytest.mark.out_of_core
@pytest.mark.parametrize("qnum", sorted(queries.QUERIES))
def test_out_of_core_sweep_streaming(qnum, data, catalog):
    """All 22 queries, device budget = 1/4 of the estimated footprint."""
    plan = queries.build_query(qnum, catalog)
    probe = Session(catalog, num_workers=1, batch_rows=4096)
    session = Session(catalog, num_workers=1, batch_rows=4096,
                      device_budget=_quarter_budget(probe, plan))
    res = session.execute(plan)
    assert_results_match(res, oracle.ORACLES[qnum](data), qnum)


@pytest.mark.out_of_core
@pytest.mark.parametrize("qnum", [1, 3, 5, 9, 13, 18, 22])
def test_out_of_core_sweep_distributed(qnum, data, catalog):
    plan = queries.build_query(qnum, catalog)
    probe = Session(catalog, num_workers=4, batch_rows=2048)
    session = Session(catalog, num_workers=4, exchange=ICIExchange(),
                      batch_rows=2048,
                      device_budget=_quarter_budget(probe, plan))
    res = session.execute(plan)
    assert_results_match(res, oracle.ORACLES[qnum](data), qnum)


@pytest.mark.out_of_core
@pytest.mark.parametrize("qnum", [1, 3, 6, 13, 14, 18])
def test_out_of_core_sweep_pallas(qnum, data, catalog):
    plan = queries.build_query(qnum, catalog)
    probe = Session(catalog, num_workers=1, batch_rows=4096)
    session = Session(catalog, num_workers=1, batch_rows=4096,
                      kernel_backend="pallas",
                      device_budget=_quarter_budget(probe, plan))
    res = session.execute(plan)
    assert_results_match(res, oracle.ORACLES[qnum](data), qnum)
