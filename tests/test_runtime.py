"""Fault-tolerance / checkpoint / data-pipeline / compression tests."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, restore_latest
from repro.configs import get_config
from repro.data import TokenPipeline
from repro.models import build_model
from repro.runtime import FailureInjector, StragglerMonitor, TrainLoop
from repro.train import make_train_step, train_state_init
from repro.train import compression


@pytest.fixture(scope="module")
def tiny():
    model = build_model(get_config("qwen2_1_5b", smoke=True))
    state = train_state_init(model, jax.random.key(0))
    step = jax.jit(make_train_step(model, base_lr=1e-3))
    corpus = np.random.default_rng(0).integers(
        0, model.cfg.vocab, 40_000).astype(np.int32)
    return model, state, step, corpus


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path, tiny):
    model, state, step, corpus = tiny
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    mgr.save(5, state, {"next_step": 6})
    got_step, got, extra = restore_latest(str(tmp_path), state)
    assert got_step == 5 and extra["next_step"] == 6
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_keeps_latest_k(tmp_path, tiny):
    _, state, _, _ = tiny
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    for s in (1, 2, 3, 4):
        mgr.save(s, state, {"next_step": s + 1})
    assert mgr.all_steps() == [3, 4]


def test_checkpoint_atomic_no_partial_dirs(tmp_path, tiny):
    _, state, _, _ = tiny
    mgr = CheckpointManager(str(tmp_path), keep=3, async_save=True)
    mgr.save(1, state, {"next_step": 2})
    mgr.wait()
    entries = [d for d in os.listdir(tmp_path) if d.startswith(".tmp")]
    assert entries == []          # tmp dir renamed away atomically


# ---------------------------------------------------------------------------
# data pipeline determinism
# ---------------------------------------------------------------------------

def test_pipeline_deterministic_resume():
    corpus = np.arange(100_000, dtype=np.int32)
    p1 = TokenPipeline(corpus, batch=4, seq_len=32)
    batches = [next(p1) for _ in range(7)]
    # resume from step 5 must reproduce batches 5, 6
    p2 = TokenPipeline.from_state(corpus, 4, 32, {"step": 5, "seed": 0})
    for want in batches[5:]:
        got = next(p2)
        np.testing.assert_array_equal(np.asarray(got["tokens"]),
                                      np.asarray(want["tokens"]))


def test_pipeline_labels_shifted():
    corpus = np.arange(10_000, dtype=np.int32)
    p = TokenPipeline(corpus, batch=2, seq_len=16)
    b = next(p)
    np.testing.assert_array_equal(np.asarray(b["tokens"][:, 1:]),
                                  np.asarray(b["labels"][:, :-1]))


# ---------------------------------------------------------------------------
# fault-tolerant training loop
# ---------------------------------------------------------------------------

def _make_loop(tmp_path, tiny, injector=None):
    model, state, step, corpus = tiny

    def pipeline_factory(start_step):
        return TokenPipeline(corpus, batch=2, seq_len=32,
                             start_step=start_step)

    return TrainLoop(step, state, pipeline_factory, str(tmp_path),
                     ckpt_every=4, injector=injector)


def test_training_recovers_from_injected_failures(tmp_path, tiny):
    clean = _make_loop(tmp_path / "clean", tiny)
    clean_state = clean.run(12)
    faulty = _make_loop(tmp_path / "faulty", tiny,
                        FailureInjector(fail_at_steps=[3, 9]))
    faulty_state = faulty.run(12)
    assert faulty.restarts == 2
    # deterministic recovery: same final params as the uninterrupted run
    for a, b in zip(jax.tree.leaves(clean_state.params),
                    jax.tree.leaves(faulty_state.params)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-6)


def test_straggler_detection_and_reassignment():
    mon = StragglerMonitor(num_workers=4, factor=3.0, window=4)
    for step in range(6):
        for w in range(4):
            mon.record(w, 1.0 if w != 2 else 10.0)   # worker 2 is slow
    flagged = mon.detect()
    assert flagged == [2]
    assert mon.healthy_workers() == [0, 1, 3]


# ---------------------------------------------------------------------------
# gradient compression
# ---------------------------------------------------------------------------

def test_quantize_dequantize_bounded_error():
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.normal(0, 1, (256, 64)), jnp.float32)
    q, s = compression.quantize(g)
    err = np.abs(np.asarray(compression.dequantize(q, s) - g))
    assert err.max() <= float(s) / 2 + 1e-7     # half-step rounding bound


def test_error_feedback_is_unbiased_over_steps():
    """With error feedback, the accumulated dequantized sum converges to
    the true gradient sum (the EF property)."""
    rng = np.random.default_rng(1)
    true = jnp.asarray(rng.normal(0, 1e-3, (128,)), jnp.float32)
    err = jnp.zeros_like(true)
    sent = jnp.zeros_like(true)
    for _ in range(50):
        q, s, err = compression.compress_tree(true, err)
        sent = sent + compression.dequantize(q, s)
    np.testing.assert_allclose(np.asarray(sent), np.asarray(true) * 50,
                               rtol=0.05, atol=1e-4)


def test_compressed_allreduce_in_shard_map():
    """End-to-end inside shard_map over a dp axis (4 host shards on one
    device still exercises the psum path)."""
    from jax.sharding import Mesh, PartitionSpec as P
    from jax.experimental.shard_map import shard_map
    devs = np.array(jax.devices()[:1]).reshape(1)
    mesh = Mesh(devs, ("dp",))
    g = jnp.asarray(np.random.default_rng(2).normal(0, 1, (1, 64)), jnp.float32)
    err = jnp.zeros_like(g)

    def f(g, e):
        out, e2 = compression.allreduce_compressed(g, e, ("dp",))
        return out, e2

    out, e2 = jax.jit(shard_map(f, mesh=mesh,
                                in_specs=(P("dp"), P("dp")),
                                out_specs=(P("dp"), P("dp"))))(g, err)
    np.testing.assert_allclose(np.asarray(out), np.asarray(g), atol=2e-2)
    assert compression.compressed_bytes(g) * 3.5 < compression.raw_bytes(g)


def test_elastic_reshard_roundtrip(tiny):
    """Reshard state across mesh shapes preserves values."""
    from repro.runtime.elastic import reshard_state
    from jax.sharding import Mesh
    model, state, _, _ = tiny
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    out = reshard_state(state.params, mesh)
    for a, b in zip(jax.tree.leaves(state.params), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
