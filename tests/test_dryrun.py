"""Dry-run machinery test: one real cell lowered + compiled on the
512-device environment in a subprocess (the full 64-cell sweep is run by
``python -m repro.launch.dryrun``; its committed results live in
results/dryrun/)."""

import json
import os
import subprocess
import sys

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_single_cell_lowers_on_production_mesh(tmp_path):
    script = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import json
import sys
from repro.launch import dryrun
rec = dryrun.lower_cell("xlstm_125m", "decode_32k", multi_pod=False)
assert rec["chips"] == 256, rec
assert rec["hlo_flops"] > 0
assert rec["roofline"]["memory_s"] > 0
rec2 = dryrun.lower_cell("qwen2_1_5b", "decode_32k", multi_pod=True)
assert rec2["chips"] == 512
assert rec2["collective_bytes_total"] > 0   # decode gathers cross chips
print("CELL_OK")
print(json.dumps({k: rec[k] for k in ("dominant", "chips")}))
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(_REPO, "src")
    env.pop("XLA_FLAGS", None)
    p = subprocess.run([sys.executable, "-c", script], env=env,
                       capture_output=True, text=True, timeout=900)
    assert p.returncode == 0, f"stdout:\n{p.stdout}\nstderr:\n{p.stderr}"
    assert "CELL_OK" in p.stdout


def test_committed_sweep_results_cover_all_cells():
    """The sweep artifact must cover every applicable (arch x shape x mesh)
    cell with no failures (assignment: 'compile must succeed for every
    combination')."""
    results = os.path.join(_REPO, "results", "dryrun")
    if not os.path.isdir(results) or not os.listdir(results):
        import pytest
        pytest.skip("sweep results not generated yet "
                    "(run python -m repro.launch.dryrun)")
    from repro.configs import ARCH_IDS, applicable_shapes, get_config
    missing, failed = [], []
    for arch in ARCH_IDS:
        for shape in applicable_shapes(get_config(arch)):
            for mesh in ("16x16", "2x16x16"):
                path = os.path.join(results, f"{arch}__{shape}__{mesh}.json")
                if not os.path.exists(path):
                    missing.append((arch, shape, mesh))
                    continue
                with open(path) as f:
                    rec = json.load(f)
                if "error" in rec:
                    failed.append((arch, shape, mesh, rec["error"][:100]))
    assert not missing, f"cells never dry-run: {missing}"
    assert not failed, f"cells failed to compile: {failed}"


def test_long_500k_only_for_subquadratic():
    from repro.configs import ARCH_IDS, applicable_shapes, get_config
    runs_long = {a for a in ARCH_IDS
                 if "long_500k" in applicable_shapes(get_config(a))}
    assert runs_long == {"xlstm_125m", "jamba_v0_1_52b"}
