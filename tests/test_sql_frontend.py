"""SQL frontend (tier-1): lowering goldens, loud failures, unified API.

Four layers, none needing optional dependencies:

* golden lowering -- one representative SQL text per construct lowers to
  the *same optimized plan fingerprint* as the equivalent hand-built
  fluent query (and lowering is deterministic across calls);
* loud unsupported surface -- every rejected construct raises
  ``SqlUnsupportedError``/``SqlParseError`` *naming the construct*; the
  engine never silently returns wrong rows;
* SQL-text TPC-H -- the 20 ported queries (``repro.tpch.sqltext``) run
  end-to-end from their SQL text and match the numpy oracle (single
  worker here; W=2 / pallas sweeps live in test_sql_oracle.py);
* unified execution API -- ``ExecutionOptions`` accepted consistently by
  ``collect``/``submit``/``run``/``Session.sql``, explain delegation, and
  the SQL-text plan/result cache key prefix.
"""

import numpy as np
import pytest

from repro.core import (ExecutionOptions, Session, SqlParseError,
                        SqlUnsupportedError)
from repro.core import plan as P
from repro.core.builder import table as _t
from repro.core.expr import col, date_lit, lit
from repro.tpch import dbgen, oracle, sqltext

from tpch_util import assert_results_match

SF = 0.002


@pytest.fixture(scope="module")
def data():
    return dbgen.generate(sf=SF)


@pytest.fixture(scope="module")
def catalog():
    return dbgen.load_catalog(sf=SF)


@pytest.fixture(scope="module")
def session(catalog):
    return Session(catalog, batch_rows=16384)


def _fp(qb, session):
    return P.fingerprint(session.optimize(qb.plan))


# ---------------------------------------------------------------------------
# golden lowering: SQL text -> same optimized fingerprint as the builder
# ---------------------------------------------------------------------------

class TestGoldenLowering:
    def test_filter_project(self, session, catalog):
        sql = session.sql(
            "SELECT l_orderkey, l_extendedprice * (1.0 - l_discount) AS rev "
            "FROM lineitem WHERE l_quantity < 24.0")
        hand = (_t(catalog, "lineitem")
                .filter(col("l_quantity") < lit(24.0))
                .project("l_orderkey",
                         rev=col("l_extendedprice")
                         * (lit(1.0) - col("l_discount"))))
        assert _fp(sql, session) == _fp(hand, session)

    def test_group_aggregate(self, session, catalog):
        sql = session.sql(
            "SELECT l_returnflag, sum(l_quantity) AS sum_qty, count(*) AS n "
            "FROM lineitem GROUP BY l_returnflag")
        # the frontend aggregates into positional slots then projects to
        # the output names -- mirror that exactly
        hand = (_t(catalog, "lineitem")
                .group_by("l_returnflag")
                .agg(__agg1=("sum", "l_quantity"), __agg2=("count", None))
                .project("l_returnflag", sum_qty=col("__agg1"),
                         n=col("__agg2")))
        assert _fp(sql, session) == _fp(hand, session)

    def test_join(self, session, catalog):
        sql = session.sql(
            "SELECT o_orderdate, l_extendedprice FROM lineitem, orders "
            "WHERE l_orderkey = o_orderkey AND o_orderdate < DATE '1995-03-15'")
        hand = (_t(catalog, "lineitem")
                .join(_t(catalog, "orders")
                      .filter(col("o_orderdate") < date_lit("1995-03-15")),
                      ["l_orderkey"], ["o_orderkey"],
                      payload=["o_orderdate"])
                .project("o_orderdate", "l_extendedprice"))
        assert _fp(sql, session) == _fp(hand, session)

    def test_semi_join_in_subquery(self, session, catalog):
        sql = session.sql(
            "SELECT count(*) AS n FROM orders WHERE o_custkey IN "
            "(SELECT c_custkey FROM customer WHERE c_acctbal > 0.0)")
        hand = (_t(catalog, "orders")
                .join(_t(catalog, "customer")
                      .filter(col("c_acctbal") > lit(0.0))
                      .project("c_custkey"),
                      ["o_custkey"], ["c_custkey"], how="left_semi")
                .agg(__agg1=("count", None))
                .project(n=col("__agg1")))
        assert _fp(sql, session) == _fp(hand, session)

    def test_anti_join_not_exists(self, session, catalog):
        sql = session.sql(
            "SELECT count(*) AS n FROM customer WHERE NOT EXISTS "
            "(SELECT * FROM orders WHERE o_custkey = c_custkey)")
        hand = (_t(catalog, "customer")
                .join(_t(catalog, "orders"),
                      ["c_custkey"], ["o_custkey"], how="left_anti")
                .agg(__agg1=("count", None))
                .project(n=col("__agg1")))
        assert _fp(sql, session) == _fp(hand, session)

    def test_order_by_limit_fuses(self, session):
        qb = session.sql("SELECT o_orderkey, o_totalprice FROM orders "
                         "ORDER BY o_totalprice DESC LIMIT 10")
        order_bys = [n for n in _walk(session.optimize(qb.plan))
                     if isinstance(n, P.OrderBy)]
        assert order_bys and order_bys[0].limit == 10

    def test_deterministic(self, session):
        text = ("SELECT l_returnflag, sum(l_quantity) AS q FROM lineitem "
                "WHERE l_shipdate <= DATE '1998-09-02' GROUP BY l_returnflag")
        assert _fp(session.sql(text), session) == \
            _fp(session.sql(text), session)


def _walk(node):
    yield node
    for c in node.children():
        yield from _walk(c)


# ---------------------------------------------------------------------------
# unsupported constructs fail loudly, naming the construct
# ---------------------------------------------------------------------------

class TestLoudFailures:
    @pytest.mark.parametrize("sql, needle", [
        ("SELECT * FROM lineitem FULL OUTER JOIN orders "
         "ON l_orderkey = o_orderkey", "FULL"),
        ("SELECT l_orderkey, sum(l_quantity) OVER () FROM lineitem",
         "OVER"),
        ("SELECT * FROM lineitem, orders", "cross join"),
        ("SELECT p_name FROM part WHERE p_name LIKE 'x_y'", "_"),
        ("SELECT count(*) AS n FROM orders o1, orders o2 "
         "WHERE o1.o_custkey = o2.o_custkey", "unique"),
    ])
    def test_unsupported_named(self, session, sql, needle):
        with pytest.raises((SqlUnsupportedError, SqlParseError)) as ei:
            session.sql(sql).collect()
        assert needle.lower() in str(ei.value).lower()

    def test_parse_error(self, session):
        with pytest.raises(SqlParseError):
            session.sql("SELEC oops FROM lineitem")

    def test_unknown_column_schema_error(self, session):
        from repro.core import SchemaError
        with pytest.raises(SchemaError):
            session.sql("SELECT nope FROM lineitem")

    def test_unported_tpch_raise_keyerror(self, catalog):
        for qnum in sqltext.UNSUPPORTED:
            with pytest.raises(KeyError):
                sqltext.sql_text(qnum, catalog)


# ---------------------------------------------------------------------------
# the 20 ported TPC-H queries, from SQL text, vs the numpy oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("qnum", sqltext.SUPPORTED)
def test_tpch_from_sql_text(qnum, session, catalog, data):
    res = session.sql(sqltext.sql_text(qnum, catalog)).collect()
    assert_results_match(res, oracle.ORACLES[qnum](data), qnum)


def test_at_least_15_queries_ported():
    assert len(sqltext.SUPPORTED) >= 15


# ---------------------------------------------------------------------------
# unified execution API
# ---------------------------------------------------------------------------

class TestUnifiedApi:
    def test_options_num_workers_collect(self, session, catalog, data):
        opts = ExecutionOptions(num_workers=2)
        res = session.sql(sqltext.sql_text(6, catalog)).collect(options=opts)
        assert_results_match(res, oracle.ORACLES[6](data), 6)

    def test_options_attached_at_sql(self, session, catalog):
        q = session.sql("SELECT count(*) AS n FROM orders",
                        options=ExecutionOptions(num_workers=2))
        base = session.sql("SELECT count(*) AS n FROM orders").collect()
        assert q.collect()["n"] == base["n"]

    def test_options_optimize_false(self, session):
        opts = ExecutionOptions(optimize=False)
        out = session.sql(
            "SELECT o_orderkey FROM orders WHERE o_orderkey <= 32 "
            "ORDER BY o_orderkey").collect(options=opts)
        keys = out["o_orderkey"]
        assert len(keys) > 0 and keys.max() <= 32
        assert list(keys) == sorted(keys)

    def test_builder_collect_shim(self, session):
        # the old positional signature still works unchanged
        out = session.table("orders").agg(n=("count", None)).collect(True)
        assert int(out["n"][0]) > 0

    def test_run_shim_accepts_plan_and_builder(self, session):
        qb = session.table("orders").agg(n=("count", None))
        assert session.run(qb.plan)["n"] == session.run(qb)["n"]

    def test_submit_options_and_sql_cache_prefix(self, session, catalog):
        text = "SELECT count(*) AS n FROM customer"
        h1 = session.sql(text).submit(
            options=ExecutionOptions(priority=3, num_workers=2))
        r1 = h1.result()
        assert h1.num_workers == 2 and h1.priority == 3
        assert h1._result_key.startswith("sql=")
        assert ":w2:" in h1._result_key
        # identical text+options -> result-cache hit under the same key
        h2 = session.sql(text).submit(
            options=ExecutionOptions(num_workers=2))
        assert h2.result()["n"] == r1["n"]
        assert h2.cache_hit
        # same logical plan WITHOUT sql text keys separately (no collision)
        h3 = session.table("customer").agg(n=("count", None)) \
            .project("n").submit()
        assert not h3._result_key.startswith("sql=")
        assert h3.result()["n"] == r1["n"]

    def test_options_kernel_backend_pinned(self, session):
        h = session.sql("SELECT count(*) AS n FROM nation").submit(
            options=ExecutionOptions(kernel_backend="jnp"))
        assert h.kernel_backend == "jnp"
        assert int(h.result()["n"][0]) == 25

    def test_explain_delegates_to_session(self, session):
        q = session.sql("SELECT count(*) AS n FROM nation")
        txt = q.explain()
        assert "TableScan" in txt or "Aggregation" in txt
        analyzed = q.explain(analyze=True)
        assert len(analyzed) > len(txt) or "rows" in analyzed

    def test_explain_unbound_analyze_raises(self, catalog):
        qb = _t(catalog, "nation").agg(n=("count", None))
        assert "Aggregation" in qb.explain()
        with pytest.raises(RuntimeError):
            qb.explain(analyze=True)

    def test_sql_results_are_numpy(self, session):
        out = session.sql("SELECT n_nationkey FROM nation "
                          "ORDER BY n_nationkey LIMIT 3").collect()
        assert isinstance(out["n_nationkey"], np.ndarray)
        assert list(out["n_nationkey"]) == [0, 1, 2]
