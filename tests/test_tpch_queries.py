"""Engine-vs-oracle validation of all 22 TPC-H queries (paper §3.4 workload).

Single-worker runs validate operator correctness; the 4-worker runs validate
the distributed path with both exchange protocols (device-native ICI and the
host-staged baseline) — all shards execute on one CPU device here, true
multi-device placement is covered by tests/test_distributed.py.
"""

import pytest

from repro.core import HostExchange, ICIExchange, Session
from repro.tpch import dbgen, oracle, queries

from tpch_util import assert_results_match

SF = 0.005


@pytest.fixture(scope="module")
def data():
    return dbgen.generate(sf=SF)


@pytest.fixture(scope="module")
def catalog():
    return dbgen.load_catalog(sf=SF)


@pytest.mark.parametrize("qnum", sorted(queries.QUERIES))
def test_query_single_worker(qnum, data, catalog):
    session = Session(catalog, num_workers=1, batch_rows=16384)
    res = session.execute(queries.build_query(qnum, catalog))
    assert_results_match(res, oracle.ORACLES[qnum](data), qnum)


# a representative subset distributed over 4 workers (full 22 runs in the
# exchange benchmark); includes exchange-heavy (5, 9), aggregation-heavy
# (1, 13), scalar-broadcast (11), anti-join (22) shapes
_DIST_QUERIES = [1, 3, 5, 9, 11, 13, 22]


@pytest.mark.parametrize("qnum", _DIST_QUERIES)
def test_query_distributed_ici(qnum, data, catalog):
    session = Session(catalog, num_workers=4, exchange=ICIExchange(),
                      batch_rows=8192)
    res = session.execute(queries.build_query(qnum, catalog))
    assert_results_match(res, oracle.ORACLES[qnum](data), qnum)


@pytest.mark.parametrize("qnum", [5, 13])
def test_query_distributed_host_exchange(qnum, data, catalog):
    session = Session(catalog, num_workers=4, exchange=HostExchange(),
                      batch_rows=8192)
    res = session.execute(queries.build_query(qnum, catalog))
    assert_results_match(res, oracle.ORACLES[qnum](data), qnum)


def test_exchange_stats_accumulate(data, catalog):
    ex = ICIExchange()
    session = Session(catalog, num_workers=4, exchange=ex, batch_rows=8192)
    session.execute(queries.build_query(5, catalog))
    assert ex.stats.rounds > 0
    assert ex.stats.bytes_moved > 0
    # device-native exchange never stages through the host
    assert ex.stats.host_staged_bytes == 0


def test_host_exchange_stages_bytes(data, catalog):
    ex = HostExchange()
    session = Session(catalog, num_workers=4, exchange=ex, batch_rows=8192)
    session.execute(queries.build_query(5, catalog))
    assert ex.stats.host_staged_bytes > 0   # the cost the paper eliminates


def test_partitioned_join_distribution(data, catalog):
    """Large-large joins via partitioned (exchange both sides) distribution."""
    from repro.core import plan as P
    plan = P.Aggregation(
        P.Join(probe=P.TableScan("lineitem", columns=["l_orderkey"]),
               build=P.TableScan("orders", columns=["o_orderkey", "o_custkey"]),
               probe_keys=["l_orderkey"], build_keys=["o_orderkey"],
               build_payload=["o_custkey"], distribution="partitioned"),
        group_keys=[], aggs=[("n", "count", None), ("s", "sum", "o_custkey")],
        max_groups=1)
    session = Session(catalog, num_workers=4, exchange=ICIExchange(),
                      batch_rows=8192)
    res = session.execute(plan)
    li, o = data["lineitem"], data["orders"]
    _, (ck,) = oracle._lookup(o["o_orderkey"], [o["o_custkey"]],
                              li["l_orderkey"])
    assert int(res["n"][0]) == len(li["l_orderkey"])
    assert int(res["s"][0]) == int(ck.sum())
