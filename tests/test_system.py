"""End-to-end behaviour tests for the paper's system: the full path from
storage through device-resident operators and exchange to results, plus the
training stack wired to the engine's data layer."""

import numpy as np

import jax

from repro.core import HostExchange, ICIExchange, Session, dtypes as dt
from repro.core import plan as P
from repro.core.expr import col
from repro.tpch import dbgen, oracle, queries


def test_full_pipeline_storage_to_result(tmp_path):
    """dbgen -> column-chunk files -> distributed scan -> join/agg ->
    oracle-validated result. The paper's H1+H2+H3 in one path."""
    data = dbgen.write_dataset(str(tmp_path), sf=0.002, chunks=4)
    catalog = dbgen.storage_catalog(str(tmp_path))
    ex = ICIExchange()
    session = Session(catalog, num_workers=4, exchange=ex, batch_rows=8192)
    res = session.execute(queries.build_query(5, catalog))
    want = oracle.ORACLES[5](data)
    assert len(res["revenue"]) == len(want["revenue"])
    np.testing.assert_allclose(np.sort(res["revenue"]),
                               np.sort(want["revenue"]), rtol=2e-3)
    assert ex.stats.host_staged_bytes == 0       # never left the device


def test_host_exchange_is_mechanism_baseline(tmp_path):
    """Both protocols agree on results; only the host one stages bytes."""
    catalog = dbgen.load_catalog(sf=0.002)
    plan = queries.build_query(13, catalog)
    res_i = Session(catalog, num_workers=4, exchange=ICIExchange(),
                    batch_rows=8192).execute(plan)
    host_ex = HostExchange()
    res_h = Session(catalog, num_workers=4, exchange=host_ex,
                    batch_rows=8192).execute(plan)
    np.testing.assert_array_equal(np.sort(res_i["c_count"]),
                                  np.sort(res_h["c_count"]))
    assert host_ex.stats.host_staged_bytes > 0


def test_driver_adaptation_inserts_conversions():
    """Declaring an operator host-only forces the CudfToVelox-style round
    trip, and the driver accounts the staged bytes (paper §3.1)."""
    catalog = dbgen.load_catalog(sf=0.002)
    session = Session(catalog, num_workers=2, batch_rows=8192,
                      host_only_ops=frozenset({"HashAggregation"}))
    plan = queries.build_query(1, catalog)
    res = session.execute(plan)
    assert len(res["sum_qty"]) == 4
    assert session.last_driver.conversion_stats.get("bytes", 0) > 0


def test_engine_feeds_training_data():
    """The engine is the framework's data substrate: filter/dedup a token
    table with a query, train on the result (paper's technique as the
    input pipeline)."""
    from repro.configs import get_config
    from repro.data import TokenPipeline
    from repro.models import build_model
    from repro.train import make_train_step, train_state_init

    rng = np.random.default_rng(0)
    catalog = dbgen.load_catalog(sf=0.001)
    catalog.register_numpy(
        "corpus",
        {"doc": np.repeat(np.arange(200), 50),
         # skewed (Zipf-flavored) tokens: a uniform vocab draw has no
         # learnable structure, leaving the loss pinned at ln(V) and the
         # loss-decreases assertion to initialization luck
         "tok": (rng.random(10_000) ** 4 * 512).astype(np.int64),
         "quality": rng.random(10_000).astype(np.float32)},
        {"doc": dt.INT32, "tok": dt.INT32, "quality": dt.FLOAT32})
    plan = P.Project(P.Filter(P.TableScan("corpus"),
                              col("quality") > 0.2), [("tok", col("tok"))])
    filtered = Session(catalog, num_workers=2, batch_rows=4096).execute(plan)
    tokens = filtered["tok"]
    assert len(tokens) > 2_000

    model = build_model(get_config("qwen2_1_5b", smoke=True))
    state = train_state_init(model, jax.random.key(0))
    # lr/steps sized so the unigram skew is actually learned: the descent
    # below ln(V) needs ~10 steps to clear per-batch noise
    step = jax.jit(make_train_step(model, base_lr=1e-2))
    pipe = TokenPipeline(tokens, batch=2, seq_len=32)
    losses = []
    for _ in range(16):
        state, m = step(state, next(pipe))
        losses.append(float(m["loss"]))
    assert np.isfinite(losses).all()
    assert losses[-1] < losses[0]
