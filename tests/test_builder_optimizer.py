"""Builder schema validation + optimizer rule tests (tree-shape assertions).

The oracle-parity of optimized TPC-H plans is covered by
test_tpch_queries.py; here we assert on the *rewritten trees* -- predicate
pushdown, projection pruning, join-distribution choice, capacity hints --
and on the builder's fail-fast schema errors.
"""

import numpy as np
import pytest

from repro.core import Session, SchemaError, dtypes as dt, plan as P
from repro.core import optimizer as opt
from repro.core.builder import table
from repro.core.expr import col, lit
from repro.tpch import dbgen, queries

SF = 0.002


@pytest.fixture(scope="module")
def catalog():
    return dbgen.load_catalog(sf=SF)


@pytest.fixture(scope="module")
def session(catalog):
    return Session(catalog, num_workers=1, batch_rows=16384)


# ---------------------------------------------------------------------------
# builder: schema propagation + fail-fast validation
# ---------------------------------------------------------------------------

def test_builder_produces_plan_ir(catalog):
    b = (table(catalog, "lineitem")
         .filter(col("l_quantity") < 10.0)
         .project("l_orderkey", v=col("l_extendedprice") * 2.0)
         .group_by("l_orderkey")
         .agg(total=("sum", "v"))
         .order_by("total", descending=[True], limit=5))
    plan = b.to_plan()
    assert isinstance(plan, P.OrderBy) and plan.limit == 5
    assert isinstance(plan.child, P.Aggregation)
    assert plan.child.group_keys == ["l_orderkey"]
    # schema propagated through every step
    assert list(b.schema) == ["l_orderkey", "total"]
    assert b.schema["total"].name == "float32"


def test_builder_unknown_table(catalog):
    with pytest.raises(SchemaError, match="unknown table"):
        table(catalog, "lineitems")


def test_builder_unknown_column_in_filter(catalog):
    with pytest.raises(SchemaError, match="unknown column.*l_shipdat"):
        table(catalog, "lineitem").filter(col("l_shipdat") < 10)


def test_builder_unknown_column_in_project(catalog):
    with pytest.raises(SchemaError, match="project"):
        table(catalog, "orders").project("o_orderkey", x=col("nope") + 1)


def test_builder_unknown_column_in_group_by_and_order_by(catalog):
    with pytest.raises(SchemaError, match="group_by"):
        table(catalog, "orders").group_by("nope")
    with pytest.raises(SchemaError, match="order_by"):
        table(catalog, "orders").order_by("nope")


def test_builder_unknown_agg_column_and_kind(catalog):
    t = table(catalog, "orders").group_by("o_custkey")
    with pytest.raises(SchemaError, match="unknown column"):
        t.agg(x=("sum", "nope"))
    with pytest.raises(SchemaError, match="unknown kind"):
        t.agg(x=("median", "o_totalprice"))


def test_builder_type_mismatch_arithmetic_on_string(catalog):
    with pytest.raises(SchemaError, match="arithmetic"):
        table(catalog, "customer").project(x=col("c_comment") + 1)
    with pytest.raises(SchemaError, match="arithmetic"):
        table(catalog, "customer").filter(
            (col("c_mktsegment") * 2) == lit(2))


def test_builder_type_mismatch_agg_over_string(catalog):
    with pytest.raises(SchemaError, match="non-numeric"):
        (table(catalog, "customer").group_by("c_nationkey")
         .agg(x=("sum", "c_comment")))


def test_builder_non_bool_filter_predicate(catalog):
    with pytest.raises(SchemaError, match="expected bool"):
        table(catalog, "orders").filter(col("o_totalprice") + 1.0)


def test_builder_pattern_predicate_needs_bytes(catalog):
    with pytest.raises(SchemaError, match="bytes column"):
        table(catalog, "orders").filter(col("o_orderkey").contains("x"))


def test_builder_join_validation(catalog):
    li = table(catalog, "lineitem")
    orders = table(catalog, "orders")
    with pytest.raises(SchemaError, match="unknown probe key"):
        li.join(orders, ["nope"], ["o_orderkey"])
    with pytest.raises(SchemaError, match="unknown build key"):
        li.join(orders, ["l_orderkey"], ["nope"])
    with pytest.raises(SchemaError, match="unknown payload"):
        li.join(orders, ["l_orderkey"], ["o_orderkey"], payload=["nope"])
    with pytest.raises(SchemaError, match="carry no build payload"):
        li.join(orders, ["l_orderkey"], ["o_orderkey"],
                payload=["o_custkey"], how="left_semi")
    with pytest.raises(SchemaError, match="key type mismatch"):
        li.join(table(catalog, "customer"), ["l_orderkey"], ["c_comment"])
    with pytest.raises(SchemaError, match="key type mismatch"):
        # int key vs float key hashes raw values -> can never match
        li.join(table(catalog, "customer"), ["l_orderkey"], ["c_acctbal"])


# ---------------------------------------------------------------------------
# optimizer rule 1: predicate pushdown
# ---------------------------------------------------------------------------

def _find(plan, node_type):
    out = []
    stack = [plan]
    while stack:
        n = stack.pop()
        if isinstance(n, node_type):
            out.append(n)
        stack.extend(n.children())
    return out


def test_pushdown_merges_filter_into_scan(catalog):
    plan = (table(catalog, "lineitem")
            .filter(col("l_quantity") < 10.0)
            .filter(col("l_discount") > 0.01)
            .project(v=col("l_extendedprice"))
            .to_plan())
    out = opt.push_filters(plan, catalog)
    assert not _find(out, P.Filter)
    scans = _find(out, P.TableScan)
    assert len(scans) == 1 and scans[0].filter is not None
    refs = scans[0].filter.references()
    assert refs == {"l_quantity", "l_discount"}


def test_pushdown_through_pure_rename_project(catalog):
    plan = P.Filter(
        P.Project(P.TableScan("orders"), [("key", col("o_orderkey"))]),
        col("key") < lit(100))
    out = opt.push_filters(plan, catalog)
    assert isinstance(out, P.Project)
    scan = out.child
    assert isinstance(scan, P.TableScan)
    assert scan.filter.references() == {"o_orderkey"}


def test_pushdown_stops_at_computed_projection(catalog):
    plan = P.Filter(
        P.Project(P.TableScan("orders"),
                  [("x", col("o_orderkey") + lit(1))]),
        col("x") < lit(100))
    out = opt.push_filters(plan, catalog)
    assert isinstance(out, P.Filter)          # not pushed past the compute
    assert _find(out, P.TableScan)[0].filter is None


# ---------------------------------------------------------------------------
# optimizer rule 2: projection pruning
# ---------------------------------------------------------------------------

def test_pruning_restricts_scan_columns(catalog):
    plan = (table(catalog, "lineitem")
            .filter(col("l_shipdate") > 9000)
            .project(v=col("l_extendedprice") * col("l_discount"))
            .agg(revenue=("sum", "v"))
            .to_plan())
    out = opt.prune_columns(opt.push_filters(plan, catalog), catalog)
    (scan,) = _find(out, P.TableScan)
    assert set(scan.columns) == {"l_shipdate", "l_extendedprice",
                                 "l_discount"}


def test_pruning_keeps_join_keys_and_payload(catalog):
    plan = (table(catalog, "lineitem")
            .join(table(catalog, "orders"), ["l_orderkey"], ["o_orderkey"],
                  payload=["o_orderdate"])
            .project("o_orderdate", q=col("l_quantity"))
            .to_plan())
    out = opt.prune_columns(plan, catalog)
    scans = {s.table: s for s in _find(out, P.TableScan)}
    assert set(scans["lineitem"].columns) == {"l_orderkey", "l_quantity"}
    assert set(scans["orders"].columns) == {"o_orderkey", "o_orderdate"}


# ---------------------------------------------------------------------------
# optimizer rule 3: join distribution from catalog row counts
# ---------------------------------------------------------------------------

def _register_rows(catalog, name, n):
    catalog.register_numpy(
        name,
        {"k": np.arange(n, dtype=np.int32) % 1000,
         "v": np.ones(n, dtype=np.float32)},
        {"k": dt.INT32, "v": dt.FLOAT32})


def test_join_distribution_choice(catalog):
    _register_rows(catalog, "big_t", (1 << 16) + 1)
    _register_rows(catalog, "small_t", 64)
    cfg = opt.OptimizerConfig()
    probe = P.TableScan("big_t")

    small = opt.choose_join_distribution(
        P.Join(probe=probe, build=P.TableScan("small_t"),
               probe_keys=["k"], build_keys=["k"]), catalog, cfg)
    assert small.distribution == "broadcast"

    big = opt.choose_join_distribution(
        P.Join(probe=probe, build=P.TableScan("big_t"),
               probe_keys=["k"], build_keys=["k"]), catalog, cfg)
    assert big.distribution == "partitioned"

    local = opt.choose_join_distribution(
        P.Join(probe=probe, build=P.TableScan("big_t"),
               probe_keys=["k"], build_keys=["k"], distribution="local"),
        catalog, cfg)
    assert local.distribution == "local"      # hand-set co-partitioning kept


# ---------------------------------------------------------------------------
# optimizer rule 5: physical exchange placement (fragment plans)
# ---------------------------------------------------------------------------

def test_place_exchanges_noop_at_one_worker(catalog):
    plan = queries.build_query(5, catalog, num_workers=1)
    assert not _find(plan, P.Repartition) and not _find(plan, P.Broadcast)


def test_place_exchanges_broadcast_join(catalog):
    _register_rows(catalog, "big_t", 4096)
    _register_rows(catalog, "small_t", 64)
    cfg = opt.OptimizerConfig(num_workers=4)
    placed = opt.optimize(
        P.Join(probe=P.TableScan("big_t"), build=P.TableScan("small_t"),
               probe_keys=["k"], build_keys=["k"], build_payload=["v"]),
        catalog, config=cfg)
    # small build replicated, join becomes co-partitioned ('local')
    assert placed.distribution == "local"
    assert isinstance(placed.build, P.Broadcast)
    assert placed.build.num_workers == 4
    assert not _find(placed, P.Repartition)


def test_place_exchanges_partitioned_join(catalog):
    _register_rows(catalog, "big_t", (1 << 16) + 1)
    cfg = opt.OptimizerConfig(num_workers=2)
    placed = opt.optimize(
        P.Join(probe=P.TableScan("big_t"), build=P.TableScan("big_t"),
               probe_keys=["k"], build_keys=["k"], build_payload=["v"]),
        catalog, config=cfg)
    assert placed.distribution == "local"
    assert isinstance(placed.probe, P.Repartition)
    assert isinstance(placed.build, P.Repartition)
    assert list(placed.probe.keys) == ["k"]


def test_place_exchanges_lowers_two_phase_aggregation(catalog):
    cfg = opt.OptimizerConfig(num_workers=4)
    placed = opt.optimize(
        P.Aggregation(P.TableScan("lineitem"), ["l_returnflag"],
                      [("n", "count", None)]), catalog, config=cfg)
    assert placed.mode == "final"
    assert isinstance(placed.child, P.Repartition)
    assert list(placed.child.keys) == ["l_returnflag"]
    assert placed.child.child.mode == "partial"
    # global (keyless) aggregation broadcasts the partials instead
    global_agg = opt.optimize(
        P.Aggregation(P.TableScan("lineitem"), [],
                      [("n", "count", None)]), catalog, config=cfg)
    assert global_agg.mode == "final"
    assert isinstance(global_agg.child, P.Broadcast)


def test_place_exchanges_never_exchanges_replicated_input(catalog):
    """An OrderBy output is replicated on every worker; exchanging it again
    would duplicate rows, so placement must stop at the Broadcast there."""
    cfg = opt.OptimizerConfig(num_workers=4)
    inner = P.OrderBy(P.TableScan("nation"), keys=["n_name"], limit=5)
    placed = opt.optimize(
        P.Aggregation(inner, ["n_regionkey"], [("n", "count", None)]),
        catalog, config=cfg)
    # the aggregation over a replicated child stays single-phase ('auto')
    assert placed.mode == "auto"
    assert not isinstance(placed.child, P.Repartition)


def test_place_exchanges_is_idempotent(catalog):
    cfg = opt.OptimizerConfig(num_workers=4)
    once = queries.build_query(5, catalog, num_workers=4)
    twice = opt.place_exchanges(once, catalog, cfg)
    assert P.fingerprint(once) == P.fingerprint(twice)


def test_fingerprint_distinguishes_worker_counts(catalog):
    w1 = queries.build_query(3, catalog, num_workers=1)
    w4 = queries.build_query(3, catalog, num_workers=4)
    assert P.fingerprint(w1) != P.fingerprint(w4)


def test_estimate_memory_prices_w_stacked_intermediates(catalog):
    """Broadcast replicas grow with W, so the admission estimate of a
    placed fragment plan must grow with worker count too."""
    plans = {w: queries.build_query(5, catalog, num_workers=w)
             for w in (1, 2, 4)}
    est = {w: opt.estimate_memory(p, catalog, num_workers=w)
           for w, p in plans.items()}
    assert est[1] < est[2] < est[4]


# ---------------------------------------------------------------------------
# optimizer rule 4: capacity hints from stats
# ---------------------------------------------------------------------------

def test_max_groups_from_dictionary_domain(catalog):
    plan = queries.build_query(1, catalog)
    (agg,) = _find(plan, P.Aggregation)
    # l_returnflag (3) x l_linestatus (2) = 6 groups + slack -> pow2 = 16
    assert agg.max_groups == 16


def test_max_groups_bounded_by_input_rows(catalog):
    n = catalog.get("orders").num_rows()
    plan = opt.optimize(
        P.Aggregation(P.TableScan("orders"), ["o_custkey"],
                      [("n", "count", None)]), catalog)
    assert plan.max_groups == opt._pow2(n + 8)


def test_global_agg_capacity_is_one(catalog):
    plan = queries.build_query(6, catalog)
    (agg,) = _find(plan, P.Aggregation)
    assert agg.max_groups == 1


def test_max_matches_one_for_unique_exact_key(catalog):
    plan = queries.build_query(14, catalog)
    (join,) = _find(plan, P.Join)
    assert join.build_keys == ["p_partkey"]   # part PK
    assert join.max_matches == 1


def test_max_matches_headroom_for_hashed_composite_key(catalog):
    plan = queries.build_query(9, catalog)
    composite = [j for j in _find(plan, P.Join)
                 if list(j.build_keys) == ["ps_partkey", "ps_suppkey"]]
    assert composite and composite[0].max_matches == 4


def test_capacity_over_budget_keeps_hand_set_max_groups(catalog, monkeypatch):
    # when the provable bound exceeds the capacity budget, the rule must
    # not silently lower a hand-set hint to the clamp
    monkeypatch.setattr(opt, "MAX_CAPACITY", 1 << 10)
    n = catalog.get("lineitem").num_rows()
    assert opt._pow2(n + 8) > (1 << 10)
    plan = opt.derive_capacities(
        P.Aggregation(P.TableScan("lineitem"), ["l_orderkey"],
                      [("n", "count", None)], max_groups=1 << 20),
        catalog)
    assert plan.max_groups == 1 << 20


def test_q18_output_schema_unchanged(catalog):
    # regression: dropping hand-listed scan columns must not leak extra
    # orders columns (o_comment & co) into q18's result contract
    schema = opt.infer_schema(queries.build_query(18, catalog), catalog)
    assert list(schema) == ["o_orderkey", "o_custkey", "o_orderdate",
                            "o_totalprice", "sum_qty", "c_name"]


def test_composite_join_headroom_without_key_stats():
    # q9/q20's composite-key joins must stay safe against catalogs that
    # declare no unique_keys (hash-bucket collisions need expansion room)
    cat = dbgen.load_catalog(sf=SF)
    for src_name in cat.tables():
        cat.get(src_name).unique_keys = ()
    plan = queries.build_query(9, cat)
    composite = [j for j in _find(plan, P.Join)
                 if list(j.build_keys) == ["ps_partkey", "ps_suppkey"]]
    assert composite and composite[0].max_matches == 4


def test_unprovable_uniqueness_keeps_hand_set_capacity(catalog):
    # build side has no declared key -> the optimizer must not lower the
    # hand-set expansion capacity
    _register_rows(catalog, "dups_t", 100)
    plan = opt.optimize(
        P.Join(probe=P.TableScan("small_t"), build=P.TableScan("dups_t"),
               probe_keys=["k"], build_keys=["k"], max_matches=7),
        catalog)
    assert plan.max_matches == 7


# ---------------------------------------------------------------------------
# end-to-end: optimized == unoptimized results, session entry points
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("qnum", [3, 6])
def test_optimized_plan_matches_unoptimized(qnum, catalog, session):
    raw = session.execute(queries.build_query(qnum, catalog, optimized=False))
    opt_res = session.execute(queries.build_query(qnum, catalog))
    assert set(raw) == set(opt_res)
    for c in raw:
        np.testing.assert_allclose(
            np.asarray(raw[c], dtype=np.float64),
            np.asarray(opt_res[c], dtype=np.float64), rtol=1e-5)


def test_session_table_collect(session):
    out = (session.table("orders")
           .filter(col("o_totalprice") > 0.0)
           .group_by("o_orderpriority")
           .agg(n=("count", None))
           .order_by("o_orderpriority")
           .collect())
    assert int(np.sum(out["n"])) == session.catalog.get("orders").num_rows()


def test_session_explain_shows_before_and_after(session, catalog):
    text = session.explain(queries.build_query(3, catalog, optimized=False))
    assert "== logical plan ==" in text
    assert "== optimized plan ==" in text
    assert "TableScan" in text and "max_groups" in text


def test_infer_schema_matches_execution(session, catalog):
    b = (session.table("lineitem")
         .project("l_orderkey", rev=col("l_extendedprice") * 0.5)
         .group_by("l_orderkey")
         .agg(revenue=("sum", "rev"), n=("count", None)))
    inferred = opt.infer_schema(b.to_plan(), catalog)
    out = b.collect()
    assert set(out) == set(inferred)
    assert inferred["n"].name == "int32"
