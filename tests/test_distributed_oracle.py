"""Property-based differential harness: distributed plans vs the CPU oracle.

Every TPC-H query is planned by the optimizer *with physical exchange
placement* (``build_query(..., num_workers=W)`` inserts explicit
Repartition/Broadcast nodes), executed through the full
builder→optimizer→distributed-driver path, and compared to the pure-numpy
oracle (``tpch/oracle.py``). Distributed results are additionally
regression-checked against the single-worker run of the same query — the
paper's correctness bar for the exchange layer ("Rethinking Analytical
Processing in the GPU Era": validate distributed execution continuously
against a CPU baseline).

Layering:

* unmarked tests — a fast smoke slice that runs in tier-1;
* ``@pytest.mark.dist_oracle`` — the full 22-query × W∈{1,2,4} ×
  both-protocols sweep plus a randomized-config property pass, deselected
  from the default run (pyproject ``addopts``) and executed as its own CI
  job. ``DIST_ORACLE_SF`` / ``DIST_ORACLE_WORKERS`` shrink it for CI.

Config generation goes through ``tests/_hypothesis_compat.seeded_given``:
the real hypothesis engine when installed, deterministic seeded-random
draws otherwise — the harness never silently skips.
"""

from __future__ import annotations

import functools
import os

import pytest

from repro.core import HostExchange, ICIExchange, Session
from repro.core import plan as P
from repro.tpch import dbgen, oracle, queries

from _hypothesis_compat import bools, sampled, seeded_given
from tpch_util import assert_results_match

SF = float(os.environ.get("DIST_ORACLE_SF", "0.002"))
WORKER_COUNTS = tuple(
    int(w) for w in os.environ.get("DIST_ORACLE_WORKERS", "1,2,4").split(","))

PROTOCOLS = {"ici": ICIExchange, "host": HostExchange}


@functools.lru_cache(maxsize=2)
def dataset(sf: float):
    """(raw numpy tables, catalog) for one scale factor, cached."""
    return dbgen.generate(sf=sf), dbgen.load_catalog(sf=sf)


def run_distributed(catalog, qnum: int, num_workers: int, proto: str,
                    batch_rows: int = 8192, streaming: bool = True,
                    prefetch_depth: int = 2):
    """Plan ``qnum`` for ``num_workers`` (exchange placement on) and run it
    on a matching session; returns (result dict, exchange protocol)."""
    plan = queries.build_query(qnum, catalog, num_workers=num_workers)
    ex = PROTOCOLS[proto]()
    session = Session(catalog, num_workers=num_workers, exchange=ex,
                      batch_rows=batch_rows, streaming=streaming,
                      prefetch_depth=prefetch_depth)
    return session.execute(plan), ex


def count_exchange_nodes(plan: P.PlanNode):
    reps = bcasts = 0
    stack = [plan]
    while stack:
        n = stack.pop()
        reps += isinstance(n, P.Repartition)
        bcasts += isinstance(n, P.Broadcast)
        stack.extend(n.children())
    return reps, bcasts


# ---------------------------------------------------------------------------
# tier-1 smoke slice (fast, always on)
# ---------------------------------------------------------------------------

def test_distributed_plans_contain_exchange_nodes():
    """The tentpole is real: W>1 planning places physical exchange nodes
    (broadcast-join builds and/or shuffles), W=1 planning places none."""
    _, catalog = dataset(SF)
    placed = 0
    for qnum in (1, 3, 5, 13):
        r1, b1 = count_exchange_nodes(
            queries.build_query(qnum, catalog, num_workers=1))
        assert (r1, b1) == (0, 0), f"q{qnum}: W=1 plan must stay exchange-free"
        r4, b4 = count_exchange_nodes(
            queries.build_query(qnum, catalog, num_workers=4))
        placed += r4 + b4
    assert placed > 0


@seeded_given(max_examples=5, qnum=sampled(1, 3, 5, 6, 13, 22),
              w=sampled(2, 4), proto=sampled("ici", "host"),
              batch_rows=sampled(2048, 8192), streaming=bools())
def test_random_distributed_config_matches_oracle(qnum, w, proto, batch_rows,
                                                  streaming):
    data, catalog = dataset(SF)
    res, ex = run_distributed(catalog, qnum, w, proto,
                              batch_rows=batch_rows, streaming=streaming)
    assert_results_match(res, oracle.ORACLES[qnum](data), qnum)
    if proto == "ici":
        assert ex.stats.host_staged_bytes == 0


def test_distributed_matches_single_worker():
    """W>1 output is bit-for-bit the W=1 output (same canonical rows)."""
    data, catalog = dataset(SF)
    for qnum in (3, 5, 13):
        base, _ = run_distributed(catalog, qnum, 1, "ici")
        assert_results_match(base, oracle.ORACLES[qnum](data), qnum)
        for w in (2, 4):
            res, _ = run_distributed(catalog, qnum, w, "ici")
            assert_results_match(res, base, qnum)


# ---------------------------------------------------------------------------
# full sweep (own CI job; deselected from tier-1 via pyproject addopts)
# ---------------------------------------------------------------------------

@pytest.mark.dist_oracle
@pytest.mark.parametrize("qnum", sorted(queries.QUERIES))
def test_full_query_sweep_both_protocols(qnum):
    """All 22 queries × W∈WORKER_COUNTS × {ici, host} vs oracle, with the
    single-worker result as the distributed regression baseline and zero
    host staging asserted for the device-native path."""
    data, catalog = dataset(SF)
    ref = oracle.ORACLES[qnum](data)
    base, _ = run_distributed(catalog, qnum, 1, "ici")
    assert_results_match(base, ref, qnum)
    for w in WORKER_COUNTS:
        if w == 1:
            continue
        for proto in PROTOCOLS:
            res, ex = run_distributed(catalog, qnum, w, proto)
            assert_results_match(res, ref, qnum)
            assert_results_match(res, base, qnum)
            # every TPC-H query aggregates or sorts, so a distributed plan
            # always crosses at least one placed exchange
            assert ex.stats.rounds > 0, (qnum, w, proto)
            if proto == "ici":
                assert ex.stats.host_staged_bytes == 0, (qnum, w)
            else:
                # any actual shuffle on the host path stages through host
                if ex.stats.rounds:
                    assert ex.stats.host_staged_bytes > 0, (qnum, w)


@pytest.mark.dist_oracle
@seeded_given(max_examples=12, _seed=20260730,
              qnum=sampled(*sorted(queries.QUERIES)),
              sf=sampled(0.001, 0.002), w=sampled(*WORKER_COUNTS),
              proto=sampled("ici", "host"),
              batch_rows=sampled(1024, 4096, 16384),
              streaming=bools(), prefetch_depth=sampled(1, 2, 4))
def test_property_random_scale_and_morsel_settings(qnum, sf, w, proto,
                                                   batch_rows, streaming,
                                                   prefetch_depth):
    """Randomized scale factor, worker count, protocol, and morsel/prefetch
    settings: the distributed result must always match the oracle."""
    data, catalog = dataset(sf)
    res, ex = run_distributed(catalog, qnum, w, proto, batch_rows=batch_rows,
                              streaming=streaming,
                              prefetch_depth=prefetch_depth)
    assert_results_match(res, oracle.ORACLES[qnum](data), qnum)
    if proto == "ici":
        assert ex.stats.host_staged_bytes == 0
