"""Optional-``hypothesis`` shim for the tier-1 suite.

When hypothesis is installed, re-exports the real ``given``/``settings``/
``strategies``. When it is not, property tests are collected but skipped,
so the rest of the suite (parametrized/example tests) still runs.
"""

from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    import pytest

    HAVE_HYPOTHESIS = False

    class _AnyStrategy:
        """Stands in for ``hypothesis.strategies``: any attribute is a
        callable returning another stand-in, so strategy expressions used
        inside ``@given(...)`` arguments still evaluate at import time."""

        def __getattr__(self, name):
            return self

        def __call__(self, *args, **kwargs):
            return self

    st = _AnyStrategy()

    def given(*args, **kwargs):
        return lambda fn: pytest.mark.skip(
            reason="hypothesis not installed")(fn)

    def settings(*args, **kwargs):
        return lambda fn: fn
