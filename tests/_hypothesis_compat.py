"""Optional-``hypothesis`` shim for the tier-1 suite.

Two levels of degradation:

* ``given``/``settings``/``st`` — re-exported verbatim when hypothesis is
  installed; without it, ``@given`` tests are collected but skipped (their
  strategies are opaque hypothesis objects we cannot draw from).

* ``seeded_given`` + the mini-strategies ``sampled``/``ints``/``bools`` —
  property tests written against these run under the real hypothesis engine
  when it is installed (strategies convert via ``to_hypothesis``), and
  degrade to ``max_examples`` deterministic seeded-random draws when it is
  not, so differential harnesses (e.g. the distributed TPC-H oracle suite)
  keep their coverage on hypothesis-less environments instead of skipping.
"""

from __future__ import annotations

import functools
import inspect
import random
import zlib

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    import pytest

    HAVE_HYPOTHESIS = False

    class _AnyStrategy:
        """Stands in for ``hypothesis.strategies``: any attribute is a
        callable returning another stand-in, so strategy expressions used
        inside ``@given(...)`` arguments still evaluate at import time."""

        def __getattr__(self, name):
            return self

        def __call__(self, *args, **kwargs):
            return self

    st = _AnyStrategy()

    def given(*args, **kwargs):
        return lambda fn: pytest.mark.skip(
            reason="hypothesis not installed")(fn)

    def settings(*args, **kwargs):
        return lambda fn: fn


# ---------------------------------------------------------------------------
# seeded-random-degradable mini-strategies
# ---------------------------------------------------------------------------

class SeededStrategy:
    """A value generator usable both ways: ``draw(rng)`` for the seeded
    fallback, ``to_hypothesis()`` when the real engine is available."""

    def draw(self, rng: random.Random):
        raise NotImplementedError

    def to_hypothesis(self):
        raise NotImplementedError


class _Sampled(SeededStrategy):
    def __init__(self, options):
        self.options = list(options)

    def draw(self, rng):
        return rng.choice(self.options)

    def to_hypothesis(self):
        from hypothesis import strategies as hst
        return hst.sampled_from(self.options)


class _Ints(SeededStrategy):
    def __init__(self, lo, hi):
        self.lo, self.hi = lo, hi

    def draw(self, rng):
        return rng.randint(self.lo, self.hi)

    def to_hypothesis(self):
        from hypothesis import strategies as hst
        return hst.integers(min_value=self.lo, max_value=self.hi)


def sampled(*options) -> SeededStrategy:
    """Uniform choice from ``options`` (st.sampled_from analogue)."""
    return _Sampled(options)


def ints(lo: int, hi: int) -> SeededStrategy:
    """Uniform integer in [lo, hi] (st.integers analogue)."""
    return _Ints(lo, hi)


def bools() -> SeededStrategy:
    """True/False (st.booleans analogue)."""
    return _Sampled([False, True])


def seeded_given(max_examples: int = 20, _seed=None, **strats: SeededStrategy):
    """Property decorator with seeded-random degradation.

    With hypothesis installed this is ``@settings(max_examples=...,
    deadline=None) @given(**converted)``. Without it, the test body runs
    ``max_examples`` times with keyword arguments drawn from a
    ``random.Random`` seeded deterministically (``_seed`` or a digest of
    the test name), so failures reproduce run-to-run; strategy kwargs may
    use any name that isn't ``max_examples``/``_seed``. Pytest fixtures
    still flow through positionally/by name as usual.
    """
    if HAVE_HYPOTHESIS:
        def deco(fn):
            hyp = {k: s.to_hypothesis() for k, s in strats.items()}
            return settings(max_examples=max_examples,
                            deadline=None)(given(**hyp)(fn))
        return deco

    def deco(fn):
        @functools.wraps(fn)
        def run(*args, **kwargs):
            base = _seed if _seed is not None else zlib.crc32(
                fn.__name__.encode())
            for i in range(max_examples):
                rng = random.Random(base * 1_000_003 + i)
                drawn = {k: s.draw(rng) for k, s in strats.items()}
                fn(*args, **drawn, **kwargs)
        # hide the drawn parameters from pytest's fixture resolution: the
        # wrapper's visible signature is fn's minus the strategy kwargs,
        # and __wrapped__ must go or pytest unwraps to fn and sees them
        sig = inspect.signature(fn)
        run.__signature__ = sig.replace(parameters=[
            p for name, p in sig.parameters.items() if name not in strats])
        del run.__wrapped__
        return run
    return deco
