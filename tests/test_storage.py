"""Storage layer tests: column-chunk format (paper §2.2), paged baseline,
data skipping, and scan integration."""

import numpy as np
import pytest

from repro.core import Session, dtypes as dt
from repro.core.expr import col, lit
from repro.storage import (ColumnChunkTable, PagedTable, write_paged_table,
                           write_table)
from repro.tpch import dbgen


@pytest.fixture(scope="module")
def dataset(tmp_path_factory):
    root = tmp_path_factory.mktemp("tpch_colchunk")
    data = dbgen.write_dataset(str(root), sf=0.002, chunks=4)
    return str(root), data


def test_colchunk_roundtrip(tmp_path):
    data = {
        "a": np.arange(100, dtype=np.int32),
        "b": np.linspace(0, 1, 100).astype(np.float32),
        "s": dt.encode_bytes([f"row{i}" for i in range(100)], 8),
        "d": np.arange(100, dtype=np.int32) % 3,
    }
    schema = {"a": dt.INT32, "b": dt.FLOAT32, "s": dt.bytes_(8),
              "d": dt.dict32(["x", "y", "z"])}
    write_table(str(tmp_path), "t", data, schema, chunks=3)
    src = ColumnChunkTable(str(tmp_path), "t")
    assert src.num_rows() == 100
    assert src.num_chunks == 3
    assert src.schema["d"].dictionary == ("x", "y", "z")
    got = {c: [] for c in data}
    for batch in src.scan(1, None, 1024):
        h = batch.to_numpy()
        for c in data:
            got[c].append(h[c])
    for c in data:
        np.testing.assert_array_equal(np.concatenate(got[c]), data[c])


def test_colchunk_scan_distributes_chunks(dataset):
    root, data = dataset
    src = ColumnChunkTable(root, "lineitem")
    rows = 0
    for batch in src.scan(4, ["l_orderkey"], 1 << 20):
        rows += int(batch.num_valid())
    assert rows == len(data["lineitem"]["l_orderkey"])


def test_paged_roundtrip(tmp_path):
    data = {"a": np.arange(1000, dtype=np.int32) * 7,
            "b": np.random.default_rng(0).random(1000).astype(np.float32)}
    schema = {"a": dt.INT32, "b": dt.FLOAT32}
    write_paged_table(str(tmp_path), "t", data, schema, row_groups=3)
    r = PagedTable(str(tmp_path), "t")
    np.testing.assert_array_equal(r.read_column("a"), data["a"])
    np.testing.assert_allclose(r.read_column("b"), data["b"])
    assert r.pages_read > 0


def test_data_skipping_prunes_chunks(tmp_path):
    # sorted column -> chunk min/max stats allow pruning
    data = {"k": np.arange(4000, dtype=np.int32)}
    write_table(str(tmp_path), "t", data, {"k": dt.INT32}, chunks=8)
    src = ColumnChunkTable(str(tmp_path), "t", skip_with_stats=True)
    pred = col("k") < lit(500)
    rows = 0
    for batch in src.scan(1, None, 1 << 20, filter_expr=pred):
        rows += int(batch.num_valid())
    assert src.chunks_skipped == 7        # only chunk 0 can contain k < 500
    assert rows == 500                    # one 500-row chunk survives


def test_query_over_storage_catalog(dataset):
    """End-to-end: TPC-H Q6 straight off the column-chunk files."""
    root, data = dataset
    from repro.tpch import oracle, queries
    cat = dbgen.storage_catalog(root)
    session = Session(cat, num_workers=2, batch_rows=16384)
    res = session.execute(queries.build_query(6, cat))
    want = oracle.ORACLES[6](data)
    np.testing.assert_allclose(res["revenue"], want["revenue"], rtol=2e-3)


def test_storage_read_counts_bytes(dataset):
    root, _ = dataset
    src = ColumnChunkTable(root, "orders")
    list(src.scan(1, ["o_orderkey"], 1 << 20))
    assert src.bytes_read == src.num_rows() * 4


def _valid_rows(batches):
    got = {}
    for b in batches:
        for c, a in b.to_numpy().items():
            got.setdefault(c, []).append(a)
    return {c: np.concatenate(v) for c, v in got.items()}


def test_paged_source_scan_matches_colchunk(dataset):
    """Write->scan round trip of the paged format equals the column-chunk
    format and the in-memory source over the same data."""
    from repro.core.session import InMemoryTable
    from repro.storage import PagedTableSource
    from repro.tpch import schema as S
    root, data = dataset
    write_paged_table(root, "orders", data["orders"], S.ORDERS, row_groups=4)
    cols = ["o_orderkey", "o_custkey", "o_totalprice"]
    mem = _valid_rows(InMemoryTable("orders", data["orders"], S.ORDERS)
                      .scan(2, cols, 4096))
    cc = _valid_rows(ColumnChunkTable(root, "orders").scan(2, cols, 4096))
    pg = _valid_rows(PagedTableSource(root, "orders").scan(2, cols, 4096))
    for c in cols:
        np.testing.assert_array_equal(np.sort(cc[c]), np.sort(mem[c]))
        np.testing.assert_array_equal(np.sort(pg[c]), np.sort(mem[c]))


def test_query_skipping_on_off_identical(dataset):
    """TPC-H Q6 through the streaming executor returns identical results
    with zone-map skipping enabled and disabled, and skipping actually
    prunes chunks (lineitem is clustered on ship date)."""
    from repro.tpch import queries
    root, _ = dataset
    cat_on = dbgen.storage_catalog(root, skip_with_stats=True)
    cat_off = dbgen.storage_catalog(root, skip_with_stats=False)
    res_on = Session(cat_on, num_workers=2).execute(
        queries.build_query(6, cat_on))
    res_off = Session(cat_off, num_workers=2).execute(
        queries.build_query(6, cat_off))
    np.testing.assert_allclose(res_on["revenue"], res_off["revenue"])
    assert cat_on.get("lineitem").chunks_skipped > 0
    assert cat_off.get("lineitem").chunks_skipped == 0
