"""Serve a small model with batched requests: prefill + batched greedy
decode over the KV cache (the decode-shape path the dry-run lowers).

    PYTHONPATH=src python examples/serve_lm.py
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import build_model


def main():
    model = build_model(get_config("qwen2_1_5b", smoke=True))
    cfg = model.cfg
    params = model.init(jax.random.key(0))

    batch, prompt_len, gen_len, max_len = 4, 24, 16, 64
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(rng.integers(0, cfg.vocab, (batch, prompt_len)),
                          jnp.int32)

    # prefill: one pass over the prompts fills every layer's KV cache
    t0 = time.perf_counter()
    logits, caches = jax.jit(model.prefill, static_argnums=2)(
        params, {"tokens": prompts}, max_len)
    next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
    t_prefill = time.perf_counter() - t0

    # batched greedy decode
    decode = jax.jit(model.decode_step)
    out_tokens = [next_tok]
    t0 = time.perf_counter()
    for i in range(gen_len - 1):
        logits, caches = decode(params, next_tok[:, None], caches,
                                jnp.int32(prompt_len + i))
        next_tok = jnp.argmax(logits[:, 0], axis=-1).astype(jnp.int32)
        out_tokens.append(next_tok)
    jax.block_until_ready(next_tok)
    t_decode = time.perf_counter() - t0

    gen = np.stack([np.asarray(t) for t in out_tokens], axis=1)
    print(f"prefill: {batch}x{prompt_len} tokens in {t_prefill * 1e3:.1f} ms")
    print(f"decode:  {gen_len} steps x {batch} seqs in "
          f"{t_decode * 1e3:.1f} ms "
          f"({gen_len * batch / t_decode:.0f} tok/s on CPU)")
    for b in range(batch):
        print(f"  request {b}: {gen[b].tolist()}")


if __name__ == "__main__":
    main()
