"""Serve N concurrent TPC-H clients through the query scheduler.

    PYTHONPATH=src python examples/serve_queries.py [--clients 8] [--sf 0.002]

Each client is a thread that submits a small dashboard of TPC-H queries
(with priorities) and waits for its results. The session's scheduler admits
them against a device-memory budget, interleaves their morsel pipelines,
coalesces duplicate in-flight queries, and serves repeats from the result
cache — the serving-engine behavior the paper's Presto coordinator provides
for its GPU workers.
"""

from __future__ import annotations

import argparse
import threading
import time

from repro.core import Session, SchedulerConfig
from repro.tpch import dbgen, queries

# a "dashboard" of quick queries each client refreshes; repeats across
# clients are exactly what the plan/result caches and coalescing serve
DASHBOARD = (1, 6, 14, 3)


def client(session, catalog, cid: int, latencies: list, errors: list) -> None:
    """One synchronous client: submit the dashboard, wait for all results."""
    try:
        handles = []
        for i, qnum in enumerate(DASHBOARD):
            plan = queries.build_query(qnum, catalog, optimized=False)
            # the freshest dashboard panel is the most urgent
            handles.append(session.submit(plan, priority=len(DASHBOARD) - i))
        for h in handles:
            h.result()
            latencies.append(h.latency)
    except Exception as exc:  # noqa: BLE001 -- surface in the summary
        errors.append((cid, exc))


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--clients", type=int, default=8)
    parser.add_argument("--sf", type=float, default=0.002)
    args = parser.parse_args()

    catalog = dbgen.load_catalog(sf=args.sf)
    session = Session(catalog, num_workers=1, batch_rows=16384)
    session.scheduler_config = SchedulerConfig(
        memory_budget=512 << 20, max_concurrency=8,
        max_queue=args.clients * len(DASHBOARD))

    latencies: list = []
    errors: list = []
    threads = [threading.Thread(target=client,
                                args=(session, catalog, c, latencies, errors))
               for c in range(args.clients)]
    t0 = time.perf_counter()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    wall = time.perf_counter() - t0

    if errors:
        raise SystemExit(f"{len(errors)} clients failed: {errors[:3]}")

    latencies.sort()
    n = len(latencies)
    stats = session.scheduler().stats()
    print(f"served {n} queries from {args.clients} clients "
          f"in {wall:.2f}s ({n / wall:.1f} q/s)")
    print(f"latency p50={latencies[n // 2] * 1e3:.1f}ms "
          f"p95={latencies[min(n - 1, int(n * 0.95))] * 1e3:.1f}ms "
          f"max={latencies[-1] * 1e3:.1f}ms")
    print(f"scheduler: completed={stats['completed']} "
          f"coalesced={stats['coalesced']} "
          f"result_cache_hits={stats['result_cache_hits']} "
          f"plan_cache_hits={stats['plan_cache_hits']} "
          f"rejected={stats['rejected']}")


if __name__ == "__main__":
    main()
