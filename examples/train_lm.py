"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps
with the full production stack — device-resident data pipeline, AdamW,
checkpointing, fault-tolerant loop (one injected failure + recovery).

    PYTHONPATH=src python examples/train_lm.py --steps 300

On this CPU container the default is a reduced model so the example
finishes in minutes; pass --full-100m on real hardware.
"""

import argparse
import tempfile

import jax
import numpy as np

from repro.configs.base import ArchConfig
from repro.data import TokenPipeline
from repro.models import build_model
from repro.runtime import FailureInjector, TrainLoop
from repro.train import make_train_step, train_state_init


def make_config(full: bool) -> ArchConfig:
    if full:   # ~100M params (xlstm-125m-class dense sibling)
        return ArchConfig(name="demo_100m", family="dense", n_layers=12,
                          d_model=768, n_heads=12, n_kv=4, d_ff=2048,
                          vocab=32_000, tie_embeddings=True)
    return ArchConfig(name="demo_small", family="dense", n_layers=4,
                      d_model=128, n_heads=4, n_kv=2, d_ff=512,
                      vocab=2_048, tie_embeddings=True)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--full-100m", action="store_true")
    args = ap.parse_args()

    cfg = make_config(args.full_100m)
    model = build_model(cfg)
    print(f"model {cfg.name}: {cfg.param_count() / 1e6:.1f}M params")

    # synthetic corpus with learnable structure (periodic + noise)
    rng = np.random.default_rng(0)
    n = 2_000_000
    base = np.arange(n) % 97
    corpus = ((base * 21 + rng.integers(0, 3, n)) % cfg.vocab).astype(np.int32)

    state = train_state_init(model, jax.random.key(0))
    step = jax.jit(make_train_step(model, base_lr=3e-4,
                                   total_steps=args.steps))

    def pipeline_factory(start_step):
        return TokenPipeline(corpus, batch=args.batch, seq_len=args.seq,
                             start_step=start_step)

    with tempfile.TemporaryDirectory() as ckpt_dir:
        loop = TrainLoop(step, state, pipeline_factory, ckpt_dir,
                         ckpt_every=50,
                         injector=FailureInjector(
                             fail_at_steps=[args.steps // 2]))
        loop.run(args.steps)
        losses = [m["loss"] for m in loop.metrics]
        print(f"restarts survived: {loop.restarts}")
        print(f"loss: step0={losses[0]:.3f} "
              f"mid={losses[len(losses) // 2]:.3f} final={losses[-1]:.3f}")
        assert losses[-1] < losses[0], "training did not reduce loss"
        print("OK: loss decreased through a mid-run failure + recovery")


if __name__ == "__main__":
    main()
