"""Quickstart: run a distributed TPC-H query on the device-resident engine.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import ICIExchange, Session, dtypes as dt, plan as P
from repro.core.expr import col, lit
from repro.tpch import dbgen, queries


def main():
    # 1) a tiny ad-hoc query on your own data ------------------------------
    catalog = dbgen.load_catalog(sf=0.002)          # TPC-H-like tables
    rng = np.random.default_rng(0)
    catalog.register_numpy(
        "events",
        {"user": rng.integers(0, 100, 5000),
         "amount": rng.random(5000).astype(np.float32) * 50},
        {"user": dt.INT32, "amount": dt.FLOAT32})

    top_spenders = P.OrderBy(
        P.Aggregation(
            P.Filter(P.TableScan("events"), col("amount") > 10.0),
            group_keys=["user"], aggs=[("spend", "sum", "amount")],
            max_groups=128),
        keys=["spend"], descending=[True], limit=5)

    session = Session(catalog, num_workers=4, exchange=ICIExchange(),
                      batch_rows=4096)
    out = session.execute(top_spenders)
    print("top spenders:", list(zip(out["user"], np.round(out["spend"], 1))))

    # 2) a real TPC-H query, distributed, data never leaves the device -----
    q5 = queries.build_query(5, catalog)
    res = session.execute(q5)
    print("\nTPC-H Q5 (revenue per nation):")
    for n, r in zip(res["n_name"], res["revenue"]):
        print(f"  nation={int(n):2d} revenue={float(r):14.2f}")
    ex = session.exchange
    print(f"\nexchange: rounds={ex.stats.rounds} "
          f"rows_moved={ex.stats.rows_moved} "
          f"host_staged_bytes={ex.stats.host_staged_bytes} (device-native!)")


if __name__ == "__main__":
    main()
