"""Quickstart: run a distributed TPC-H query on the device-resident engine.

    PYTHONPATH=src python examples/quickstart.py

(or ``pip install -e .`` once and drop the PYTHONPATH.)
"""

import numpy as np

from repro.core import ICIExchange, Session, dtypes as dt
from repro.core.expr import col
from repro.tpch import dbgen, queries


def main():
    # 1) a tiny ad-hoc query on your own data, in the fluent builder API ---
    #    every step validates column names/types against the propagated
    #    schema, and .collect() runs the plan through the rule-based
    #    optimizer (predicate pushdown, column pruning, join distribution,
    #    capacity hints) before the driver executes it.
    catalog = dbgen.load_catalog(sf=0.002)          # TPC-H-like tables
    rng = np.random.default_rng(0)
    catalog.register_numpy(
        "events",
        {"user": rng.integers(0, 100, 5000),
         "amount": rng.random(5000).astype(np.float32) * 50},
        {"user": dt.INT32, "amount": dt.FLOAT32},
        unique_keys=())

    session = Session(catalog, num_workers=4, exchange=ICIExchange(),
                      batch_rows=4096)

    top_spenders = (session.table("events")
                    .filter(col("amount") > 10.0)
                    .group_by("user")
                    .agg(spend=("sum", "amount"))
                    .order_by("spend", descending=[True], limit=5))

    print(top_spenders.explain())                   # plan before/after rules
    out = top_spenders.collect()
    print("\ntop spenders:",
          list(zip(out["user"], np.round(out["spend"], 1))))

    # 2) a real TPC-H query, distributed, data never leaves the device -----
    q5 = queries.build_query(5, catalog)            # optimizer-planned tree
    res = session.execute(q5)
    print("\nTPC-H Q5 (revenue per nation):")
    for n, r in zip(res["n_name"], res["revenue"]):
        print(f"  nation={int(n):2d} revenue={float(r):14.2f}")
    ex = session.exchange
    print(f"\nexchange: rounds={ex.stats.rounds} "
          f"rows_moved={ex.stats.rows_moved} "
          f"host_staged_bytes={ex.stats.host_staged_bytes} (device-native!)")


if __name__ == "__main__":
    main()
