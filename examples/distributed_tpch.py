"""Distributed TPC-H on a real multi-device mesh with both exchange
protocols — the paper's Figure 5 experiment in miniature.

Run with forced host devices to see true multi-device placement:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/distributed_tpch.py
"""

import time

import jax

from repro.core import HostExchange, ICIExchange, Session
from repro.launch.mesh import make_engine_mesh
from repro.tpch import dbgen, queries


def main():
    n_dev = jax.device_count()
    workers = min(n_dev, 8)
    mesh = make_engine_mesh(workers) if n_dev >= workers > 1 else None
    print(f"devices={n_dev}, workers={workers}, mesh={'yes' if mesh else 'no'}")

    catalog = dbgen.load_catalog(sf=0.002)
    for q in (1, 5, 9, 13):
        row = [f"q{q}"]
        for name, ex in (("ICI", ICIExchange(mesh=mesh)),
                         ("Host", HostExchange())):
            session = Session(catalog, num_workers=workers, exchange=ex,
                              batch_rows=8192, mesh=mesh)
            plan = queries.build_query(q, catalog)
            session.execute(plan)           # warm
            t0 = time.perf_counter()
            session.execute(plan)
            dt = time.perf_counter() - t0
            row.append(f"{name}={dt * 1e3:7.1f}ms staged="
                       f"{ex.stats.host_staged_bytes:>9d}B")
        print("  ".join(row))
    print("\nICI keeps the working set in device memory (staged=0); the "
          "host protocol round-trips every exchanged byte (paper §3.3).")


if __name__ == "__main__":
    main()
